//! Machine-maintenance **semi-MDP** with exponential sojourn times — the
//! scenario class that needs state-action-dependent discounting
//! (DESIGN.md §12; the companion madupite paper's generalized-discount
//! support is exactly for models like this).
//!
//! A machine deteriorates through wear levels `0` (new) … `n − 1`
//! (failed), but unlike the discrete-time [`super::replacement`] model the
//! time between decision epochs is **random**: in wear level `c` under
//! action `a` the machine holds for an exponential sojourn `τ ~ Exp(r(c,a))`
//! before the next transition. Costs accrue at a *rate* while the sojourn
//! lasts, and future cost is discounted continuously at rate `ρ > 0`.
//! Standard semi-MDP algebra turns this into an equivalent discrete model
//! with per-transition quantities:
//!
//! - effective discount `γ(c,a) = E[e^{−ρτ}] = r(c,a) / (r(c,a) + ρ)`
//!   (the Laplace transform of the exponential sojourn at `ρ`), and
//! - stage cost: this model uses the expected **undiscounted** sojourn
//!   cost `g(c,a) = c_rate(c,a) · E[τ] = c_rate(c,a) / r(c,a)` — the
//!   ρ → 0 limit of the fully discounted integral
//!   `c_rate · (1 − γ(c,a)) / ρ`. The deliberate simplification keeps
//!   stage costs independent of the `-gamma` knob (like every other
//!   catalog model, whose `cost(s, a)` has no gamma parameter) while
//!   preserving the time-scale trade-off that makes the model a semi-MDP:
//!   slow states accrue more cost per decision epoch, fast states
//!   discount the future less per epoch.
//!
//! The base `-gamma` knob keeps its usual meaning as the *per unit time*
//! discount, mapped to the continuous rate via `ρ = −ln γ`. Worn machines
//! fail faster (`r` grows with wear), so their sojourns are shorter and
//! their effective discounts *larger* — the future matters more per
//! decision exactly where decisions come thick — which is why collapsing
//! γ(c,a) to any single scalar changes the optimal policy, not just the
//! values. Repairs also take time (`Exp(repair_rate)`), discounting the
//! post-repair future accordingly.

use super::ModelGenerator;

/// Semi-MDP machine-maintenance specification (all rates per unit time).
#[derive(Clone, Debug)]
pub struct MaintenanceSpec {
    /// Number of wear levels (0 = new, last = failed). At least 3.
    pub n_conditions: usize,
    /// Degradation rate of a new machine under "run" (sojourn `Exp(rate)`).
    pub base_rate: f64,
    /// Additional degradation rate per unit of relative wear: the rate at
    /// wear `c` is `base_rate · (1 + accel · c/(n−1))` — worn machines
    /// fail faster, shortening sojourns and *raising* γ(c, run).
    pub accel: f64,
    /// Probability that a degradation step jumps two levels (shock).
    pub shock_prob: f64,
    /// Completion rate of a repair (sojourn `Exp(repair_rate)`).
    pub repair_rate: f64,
    /// Operating-cost rate at wear `c`: `operating_base + slope · (c/(n−1))²`.
    pub operating_base: f64,
    /// Slope of the convex operating-cost-rate curve.
    pub operating_slope: f64,
    /// Cost rate while a repair is in progress (parts + downtime).
    pub repair_cost_rate: f64,
    /// Extra cost rate of running a failed machine (outage).
    pub outage_cost_rate: f64,
}

impl MaintenanceSpec {
    /// The standard benchmark parameterization with `n_conditions` levels.
    pub fn standard(n_conditions: usize) -> MaintenanceSpec {
        assert!(n_conditions >= 3);
        MaintenanceSpec {
            n_conditions,
            base_rate: 0.5,
            accel: 4.0,
            shock_prob: 0.1,
            repair_rate: 2.0,
            operating_base: 0.2,
            operating_slope: 5.0,
            repair_cost_rate: 6.0,
            outage_cost_rate: 4.0,
        }
    }

    fn failed(&self) -> usize {
        self.n_conditions - 1
    }

    /// Sojourn rate `r(c, a)` (a = 0 run, a = 1 repair).
    pub fn sojourn_rate(&self, c: usize, a: usize) -> f64 {
        if a == 1 {
            self.repair_rate
        } else {
            let frac = c as f64 / (self.n_conditions - 1) as f64;
            self.base_rate * (1.0 + self.accel * frac)
        }
    }

    /// Continuous discount rate `ρ = −ln γ` for the per-unit-time `gamma`.
    pub fn rho(gamma: f64) -> f64 {
        -gamma.ln()
    }

    /// Cost *rate* while in wear level `c` under action `a`.
    pub fn cost_rate(&self, c: usize, a: usize) -> f64 {
        if a == 1 {
            self.repair_cost_rate
        } else {
            let frac = c as f64 / (self.n_conditions - 1) as f64;
            let run = self.operating_base + self.operating_slope * frac * frac;
            if c == self.failed() {
                run + self.outage_cost_rate
            } else {
                run
            }
        }
    }
}

impl ModelGenerator for MaintenanceSpec {
    fn n_states(&self) -> usize {
        self.n_conditions
    }

    fn n_actions(&self) -> usize {
        2
    }

    fn prob_row(&self, c: usize, a: usize) -> Vec<(usize, f64)> {
        if a == 1 {
            // repair completes to a new machine
            return vec![(0, 1.0)];
        }
        if c == self.failed() {
            // a failed machine stays failed until repaired
            return vec![(c, 1.0)];
        }
        let one = (c + 1).min(self.failed());
        let two = (c + 2).min(self.failed());
        if one == two {
            vec![(one, 1.0)]
        } else {
            vec![(one, 1.0 - self.shock_prob), (two, self.shock_prob)]
        }
    }

    fn cost(&self, c: usize, a: usize) -> f64 {
        // expected undiscounted sojourn cost c_rate(c,a) · E[τ] — see the
        // module docs for why the ρ → 0 limit is the stage cost here
        self.cost_rate(c, a) / self.sojourn_rate(c, a)
    }

    fn discount(&self, c: usize, a: usize, gamma: f64) -> f64 {
        let r = self.sojourn_rate(c, a);
        r / (r + Self::rho(gamma))
    }

    fn has_discounts(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mdp::{Discount, DiscountMode};
    use crate::models::check_generator;
    use crate::solver::{solve_serial, Method, SolveOptions};

    #[test]
    fn generator_valid() {
        check_generator(&MaintenanceSpec::standard(12));
    }

    #[test]
    fn effective_discount_formula() {
        let spec = MaintenanceSpec::standard(10);
        let gamma = 0.9;
        let rho = -0.9f64.ln();
        for c in 0..10 {
            for a in 0..2 {
                let r = spec.sojourn_rate(c, a);
                let want = r / (r + rho);
                assert!((spec.discount(c, a, gamma) - want).abs() < 1e-15);
                assert!(spec.discount(c, a, gamma) < 1.0);
            }
        }
        // worn machines transition faster → larger effective discount
        assert!(spec.discount(9, 0, gamma) > spec.discount(0, 0, gamma));
    }

    #[test]
    fn builds_a_per_state_action_semi_mdp() {
        let spec = MaintenanceSpec::standard(8);
        let mdp = spec.build_serial(0.9);
        assert_eq!(mdp.discount().mode(), DiscountMode::PerStateAction);
        match mdp.discount() {
            Discount::PerStateAction(v) => {
                assert_eq!(v.len(), 16);
                assert_eq!(v[0], spec.discount(0, 0, 0.9));
                assert_eq!(v[15], spec.discount(7, 1, 0.9));
            }
            other => panic!("unexpected discount {other:?}"),
        }
        // the contraction bound is the max over all pairs
        let gmax = (0..8)
            .flat_map(|c| (0..2).map(move |a| (c, a)))
            .map(|(c, a)| spec.discount(c, a, 0.9))
            .fold(0.0f64, f64::max);
        assert_eq!(mdp.gamma(), gmax);
    }

    #[test]
    fn optimal_policy_is_control_limit() {
        let spec = MaintenanceSpec::standard(16);
        let mdp = spec.build_serial(0.95);
        let r = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::ipi_gmres(),
                atol: 1e-10,
                ..Default::default()
            },
        );
        assert!(r.converged);
        // run when new, repair when failed
        assert_eq!(r.policy[0], 0);
        assert_eq!(r.policy[15], 1);
        // monotone threshold structure: once repair, always repair
        let first = r.policy.iter().position(|&a| a == 1).unwrap();
        for c in first..16 {
            assert_eq!(r.policy[c], 1, "not a control limit: {:?}", r.policy);
        }
        // value increasing in wear
        for c in 1..16 {
            assert!(r.value[c] >= r.value[c - 1] - 1e-9);
        }
    }

    #[test]
    fn semi_mdp_differs_from_scalar_collapse() {
        // The point of per-transition discounting: collapsing γ(c,a) to
        // the scalar bound γ̄ solves a *different* model — the values move
        // substantially (a new machine's future is over-discounted by the
        // failed machine's short sojourns), so the scenario class is
        // genuinely unreachable with one scalar.
        let spec = MaintenanceSpec::standard(16);
        let semi = spec.build_serial(0.95);
        let scalar = crate::mdp::Mdp::new(
            16,
            2,
            semi.transitions().clone(),
            semi.costs().to_vec(),
            semi.gamma(),
        )
        .unwrap();
        let opts = SolveOptions {
            method: Method::ipi_gmres(),
            atol: 1e-10,
            ..Default::default()
        };
        let r_semi = solve_serial(&semi, &opts);
        let r_scalar = solve_serial(&scalar, &opts);
        assert!(r_semi.converged && r_scalar.converged);
        let max_diff = r_semi
            .value
            .iter()
            .zip(&r_scalar.value)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(max_diff > 0.1, "scalar collapse barely moved the values (max diff {max_diff})");
    }
}
