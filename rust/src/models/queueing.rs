//! Queueing admission-control MDP (uniformized M/M/1/K).
//!
//! State = number of jobs in the system `0..=K`. On each (uniformized)
//! event the controller decides whether an arriving job is admitted.
//! Actions: 0 = admit arrivals, 1 = reject arrivals. Per-period cost =
//! holding · q + rejection penalty · (arrival mass turned away). The
//! optimal policy is a threshold: admit below a critical queue length.

use super::ModelGenerator;

/// Admission-control specification.
#[derive(Clone, Debug)]
pub struct QueueSpec {
    /// System capacity (states 0..=K).
    pub capacity: usize,
    /// Arrival rate λ.
    pub lambda: f64,
    /// Service rate μ.
    pub mu: f64,
    /// Cost per job per period in the system.
    pub holding_cost: f64,
    /// Penalty per rejected arrival.
    pub rejection_cost: f64,
}

impl QueueSpec {
    /// The standard benchmark parameterization for a given capacity.
    pub fn standard(capacity: usize) -> QueueSpec {
        QueueSpec {
            capacity,
            lambda: 0.6,
            mu: 0.5,
            holding_cost: 0.2,
            rejection_cost: 3.0,
        }
    }

    /// Uniformized event probabilities: (arrival, departure, self-loop).
    fn event_probs(&self) -> (f64, f64, f64) {
        let total = self.lambda + self.mu;
        // uniformization constant slightly above λ+μ keeps a self-loop
        let c = total * 1.1;
        (self.lambda / c, self.mu / c, 1.0 - total / c)
    }
}

impl ModelGenerator for QueueSpec {
    fn n_states(&self) -> usize {
        self.capacity + 1
    }

    fn n_actions(&self) -> usize {
        2
    }

    fn prob_row(&self, q: usize, a: usize) -> Vec<(usize, f64)> {
        let (p_arr, p_dep, p_self) = self.event_probs();
        let admit = a == 0 && q < self.capacity;
        let mut row: Vec<(usize, f64)> = Vec::with_capacity(3);
        let mut push = |t: usize, p: f64| {
            if p <= 0.0 {
                return;
            }
            match row.iter_mut().find(|(tt, _)| *tt == t) {
                Some((_, pp)) => *pp += p,
                None => row.push((t, p)),
            }
        };
        // arrival event
        push(if admit { q + 1 } else { q }, p_arr);
        // departure event
        push(q.saturating_sub(1), p_dep);
        if q == 0 {
            // no departure possible: fold the mass into the self-loop
        }
        // self-loop
        push(q, p_self);
        row.sort_by_key(|&(t, _)| t);
        row
    }

    fn cost(&self, q: usize, a: usize) -> f64 {
        let (p_arr, _, _) = self.event_probs();
        let rejects = a == 1 || q == self.capacity;
        self.holding_cost * q as f64
            + if rejects { self.rejection_cost * p_arr } else { 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::check_generator;
    use crate::models::ModelGenerator;
    use crate::solver::{solve_serial, SolveOptions};

    #[test]
    fn generator_valid() {
        check_generator(&QueueSpec::standard(10));
    }

    #[test]
    fn event_probs_sum_to_one() {
        let q = QueueSpec::standard(5);
        let (a, d, s) = q.event_probs();
        assert!((a + d + s - 1.0).abs() < 1e-12);
        assert!(s > 0.0, "uniformization must leave a self-loop");
    }

    #[test]
    fn admit_moves_up_reject_does_not() {
        let spec = QueueSpec::standard(5);
        let up_admit: f64 = spec
            .prob_row(2, 0)
            .iter()
            .filter(|&&(t, _)| t == 3)
            .map(|&(_, p)| p)
            .sum();
        let up_reject: f64 = spec
            .prob_row(2, 1)
            .iter()
            .filter(|&&(t, _)| t == 3)
            .map(|&(_, p)| p)
            .sum();
        assert!(up_admit > 0.0);
        assert_eq!(up_reject, 0.0);
    }

    #[test]
    fn empty_queue_no_departure_mass_below_zero() {
        let spec = QueueSpec::standard(5);
        for a in 0..2 {
            for &(t, _) in &spec.prob_row(0, a) {
                assert!(t <= 1);
            }
        }
    }

    #[test]
    fn full_queue_cannot_grow() {
        let spec = QueueSpec::standard(4);
        for a in 0..2 {
            for &(t, _) in &spec.prob_row(4, a) {
                assert!(t <= 4);
            }
        }
    }

    #[test]
    fn optimal_policy_is_threshold() {
        let spec = QueueSpec::standard(12);
        let mdp = spec.build_serial(0.98);
        let r = solve_serial(
            &mdp,
            &SolveOptions {
                atol: 1e-9,
                ..Default::default()
            },
        );
        assert!(r.converged);
        // admit when empty (cheap), reject near capacity (holding dominates)
        assert_eq!(r.policy[0], 0, "should admit into an empty system");
        // policy must be monotone: once it rejects it keeps rejecting.
        // q = capacity is excluded: admit and reject are *identical* there
        // (arrivals are blocked either way), so the argmin tie-breaks to 0.
        let first_reject = r.policy[..12].iter().position(|&a| a == 1);
        if let Some(k) = first_reject {
            for q in k..12 {
                assert_eq!(r.policy[q], 1, "non-threshold policy: {:?}", r.policy);
            }
        }
    }
}
