//! Garnet MDPs — Generalized Average Reward Non-stationary Environment
//! Testbench (Archibald et al.), the standard random-MDP family used by
//! the iPI companion paper for controlled sweeps (E3/E4): size `n`,
//! actions `m`, branching factor `b` (successors per state–action), all
//! structure drawn deterministically from a seed.

use super::ModelGenerator;
use crate::util::prng::Xoshiro256pp;

/// Garnet specification.
#[derive(Clone, Debug)]
pub struct GarnetSpec {
    /// Number of states.
    pub n_states: usize,
    /// Number of actions.
    pub n_actions: usize,
    /// Successors per (s, a) — controls sparsity: nnz = n·m·b.
    pub branching: usize,
    /// PRNG seed (the spec is a pure function of it).
    pub seed: u64,
}

impl GarnetSpec {
    /// Garnet spec with the given shape, branching factor and seed.
    pub fn new(n_states: usize, n_actions: usize, branching: usize, seed: u64) -> GarnetSpec {
        assert!(branching >= 1 && branching <= n_states);
        GarnetSpec {
            n_states,
            n_actions,
            branching,
            seed,
        }
    }

    /// Per-(s,a) deterministic RNG stream.
    fn rng(&self, s: usize, a: usize) -> Xoshiro256pp {
        let key = (s as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(a as u64)
            .wrapping_mul(0xBF58476D1CE4E5B9)
            ^ self.seed;
        Xoshiro256pp::new(key)
    }
}

impl ModelGenerator for GarnetSpec {
    fn n_states(&self) -> usize {
        self.n_states
    }

    fn n_actions(&self) -> usize {
        self.n_actions
    }

    fn prob_row(&self, s: usize, a: usize) -> Vec<(usize, f64)> {
        let mut rng = self.rng(s, a);
        // b distinct successors by rejection — O(b²) instead of the O(n)
        // allocation of a full Fisher–Yates, which matters at n = 10⁶
        // (generation is rank-local and must stay linear in local size).
        let mut targets: Vec<usize> = Vec::with_capacity(self.branching);
        while targets.len() < self.branching {
            let t = rng.index(self.n_states);
            if !targets.contains(&t) {
                targets.push(t);
            }
        }
        let probs = rng.prob_vector(self.branching);
        let mut row: Vec<(usize, f64)> = targets.into_iter().zip(probs).collect();
        row.sort_by_key(|&(t, _)| t);
        row
    }

    fn cost(&self, s: usize, a: usize) -> f64 {
        // independent stream so costs do not correlate with structure
        let mut rng = self.rng(s ^ 0x5151, a ^ 0x77);
        rng.next_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::check_generator;
    use crate::solver::{solve_serial, SolveOptions};

    #[test]
    fn generator_valid() {
        check_generator(&GarnetSpec::new(40, 4, 3, 123));
    }

    #[test]
    fn deterministic_in_seed() {
        let a = GarnetSpec::new(20, 3, 5, 9);
        let b = GarnetSpec::new(20, 3, 5, 9);
        let c = GarnetSpec::new(20, 3, 5, 10);
        for s in 0..20 {
            for act in 0..3 {
                assert_eq!(a.prob_row(s, act), b.prob_row(s, act));
                assert_eq!(a.cost(s, act), b.cost(s, act));
            }
        }
        assert!((0..20).any(|s| a.prob_row(s, 0) != c.prob_row(s, 0)));
    }

    #[test]
    fn branching_respected() {
        let g = GarnetSpec::new(50, 2, 7, 3);
        for s in 0..50 {
            let row = g.prob_row(s, 1);
            assert_eq!(row.len(), 7);
            let mut t: Vec<usize> = row.iter().map(|&(c, _)| c).collect();
            t.dedup();
            assert_eq!(t.len(), 7, "duplicate successors");
        }
    }

    #[test]
    fn solvable() {
        let g = GarnetSpec::new(60, 3, 4, 11);
        let mdp = g.build_serial(0.95);
        let r = solve_serial(
            &mdp,
            &SolveOptions {
                atol: 1e-8,
                ..Default::default()
            },
        );
        assert!(r.converged);
        // values bounded by max cost / (1−γ) = 1/0.05 = 20
        assert!(r.value.iter().all(|&v| (0.0..=20.0).contains(&v)));
    }
}
