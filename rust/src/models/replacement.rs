//! Machine-replacement MDP (Feinberg & Shwartz 2002's classic operations
//! example; also the standard "structured optimal policy" testbed).
//!
//! A machine deteriorates through condition states `0` (new) …
//! `n_conditions − 1` (failed). Each period: **keep** (action 0) — pay an
//! operating cost increasing in wear, and the machine degrades
//! stochastically; or **replace** (action 1) — pay a fixed replacement
//! cost and restart from condition 0. The optimal policy is a *control
//! limit*: replace iff condition ≥ threshold — asserted by the tests, and
//! a good target for `Objective::Max` reward-mode coverage (profit form).

use super::ModelGenerator;

/// Machine-replacement specification.
#[derive(Clone, Debug)]
pub struct ReplacementSpec {
    /// Number of condition states (0 = new, last = failed).
    pub n_conditions: usize,
    /// Per-period probability of degrading one condition step.
    pub wear_prob: f64,
    /// Probability of a sudden two-step degradation (shock).
    pub shock_prob: f64,
    /// Operating cost at condition c: `base + slope · c²/(n−1)²` (convex).
    pub operating_base: f64,
    /// Slope of the convex operating-cost curve.
    pub operating_slope: f64,
    /// Cost of replacing the machine (paid once, restart at condition 0).
    pub replacement_cost: f64,
}

impl ReplacementSpec {
    /// The standard benchmark parameterization with `n_conditions` states.
    pub fn standard(n_conditions: usize) -> ReplacementSpec {
        assert!(n_conditions >= 3);
        ReplacementSpec {
            n_conditions,
            wear_prob: 0.3,
            shock_prob: 0.05,
            operating_base: 0.2,
            operating_slope: 4.0,
            replacement_cost: 6.0,
        }
    }

    fn failed(&self) -> usize {
        self.n_conditions - 1
    }

    /// Convex operating cost in the wear level.
    pub fn operating_cost(&self, c: usize) -> f64 {
        let frac = c as f64 / (self.n_conditions - 1) as f64;
        self.operating_base + self.operating_slope * frac * frac
    }
}

impl ModelGenerator for ReplacementSpec {
    fn n_states(&self) -> usize {
        self.n_conditions
    }

    fn n_actions(&self) -> usize {
        2
    }

    fn prob_row(&self, c: usize, a: usize) -> Vec<(usize, f64)> {
        if a == 1 {
            // replace: next period starts from a new machine
            return vec![(0, 1.0)];
        }
        if c == self.failed() {
            // a failed machine stays failed until replaced
            return vec![(c, 1.0)];
        }
        let one = (c + 1).min(self.failed());
        let two = (c + 2).min(self.failed());
        let mut row: Vec<(usize, f64)> = Vec::with_capacity(3);
        let stay = 1.0 - self.wear_prob - self.shock_prob;
        row.push((c, stay));
        if one == two {
            row.push((one, self.wear_prob + self.shock_prob));
        } else {
            row.push((one, self.wear_prob));
            row.push((two, self.shock_prob));
        }
        row
    }

    fn cost(&self, c: usize, a: usize) -> f64 {
        if a == 1 {
            self.replacement_cost
        } else if c == self.failed() {
            // running a failed machine: maximal operating cost plus outage
            self.operating_cost(c) + 2.0
        } else {
            self.operating_cost(c)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mdp::Objective;
    use crate::models::check_generator;
    use crate::solver::{solve_serial, Method, SolveOptions};

    #[test]
    fn generator_valid() {
        check_generator(&ReplacementSpec::standard(12));
    }

    #[test]
    fn replace_resets_to_new() {
        let r = ReplacementSpec::standard(8);
        for c in 0..8 {
            assert_eq!(r.prob_row(c, 1), vec![(0, 1.0)]);
        }
    }

    #[test]
    fn failed_machine_absorbs_under_keep() {
        let r = ReplacementSpec::standard(8);
        assert_eq!(r.prob_row(7, 0), vec![(7, 1.0)]);
        assert!(r.cost(7, 0) > r.cost(6, 0));
    }

    #[test]
    fn operating_cost_convex_increasing() {
        let r = ReplacementSpec::standard(10);
        for c in 1..10 {
            assert!(r.operating_cost(c) > r.operating_cost(c - 1));
        }
        // convexity: second difference nonnegative
        for c in 2..10 {
            let d2 = r.operating_cost(c) - 2.0 * r.operating_cost(c - 1)
                + r.operating_cost(c - 2);
            assert!(d2 >= -1e-12);
        }
    }

    #[test]
    fn optimal_policy_is_control_limit() {
        let spec = ReplacementSpec::standard(20);
        let mdp = spec.build_serial(0.95);
        let r = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::ipi_gmres(),
                atol: 1e-10,
                ..Default::default()
            },
        );
        assert!(r.converged);
        // keep when new
        assert_eq!(r.policy[0], 0);
        // failed machine must be replaced
        assert_eq!(r.policy[19], 1);
        // monotone threshold structure: once replace, always replace
        let first = r.policy.iter().position(|&a| a == 1).unwrap();
        for c in first..20 {
            assert_eq!(r.policy[c], 1, "not a control limit: {:?}", r.policy);
        }
        // value increasing in wear
        for c in 1..20 {
            assert!(r.value[c] >= r.value[c - 1] - 1e-9);
        }
    }

    #[test]
    fn max_reward_mode_mirrors_min_cost() {
        // Negate costs and maximize: identical policy, negated values —
        // exercises Objective::Max end-to-end through every method.
        let spec = ReplacementSpec::standard(15);
        let min_mdp = spec.build_serial(0.9);
        let max_mdp = crate::mdp::Mdp::new(
            15,
            2,
            min_mdp.transitions().clone(),
            min_mdp.costs().iter().map(|c| -c).collect(),
            0.9,
        )
        .unwrap()
        .with_objective(Objective::Max);

        for method in [Method::Vi, Method::Mpi { sweeps: 10 }, Method::ipi_gmres()] {
            let opts = SolveOptions {
                method,
                atol: 1e-10,
                max_outer: 100_000,
                ..Default::default()
            };
            let rmin = solve_serial(&min_mdp, &opts);
            let rmax = solve_serial(&max_mdp, &opts);
            assert!(rmin.converged && rmax.converged);
            assert_eq!(rmin.policy, rmax.policy);
            for (a, b) in rmin.value.iter().zip(&rmax.value) {
                assert!((a + b).abs() < 1e-7, "{a} vs {b}");
            }
        }
    }
}
