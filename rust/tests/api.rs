//! Embedded-API and options-database tests: key validation, spelling
//! round-trips, builder validation, output files, and CLI-vs-API parity.

use madupite::api::{self, MdpBuilder, Solver};
use madupite::ksp::precond::PcType;
use madupite::ksp::KspType;
use madupite::mdp::Objective;
use madupite::solver::{EvalBackend, Method};
use madupite::util::args::Options;
use madupite::util::json::Json;
use std::path::PathBuf;

fn db(toks: &[&str]) -> Options {
    Options::parse(toks.iter().map(|s| s.to_string()))
}

fn tmpfile(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("madupite_api_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{}_{name}", std::process::id()))
}

fn two_state_builder() -> MdpBuilder {
    MdpBuilder::from_fillers(
        2,
        2,
        |s, a| match (s, a) {
            (0, 0) => vec![(0, 1.0)],
            (0, 1) => vec![(1, 1.0)],
            _ => vec![(1, 1.0)],
        },
        |s, a| match (s, a) {
            (0, 0) => 1.0,
            (0, 1) => 1.5,
            _ => 0.0,
        },
    )
    .gamma(0.5)
}

/// Unknown keys are hard errors with a nearest-key suggestion in the
/// embedded path — the `-ksp_tpye gmres` typo can no longer silently
/// solve with the default method.
#[test]
fn api_unknown_key_is_hard_error() {
    let mut solver = Solver::new(two_state_builder());
    let err = solver.set_option("-ksp_tpye", "gmres").unwrap_err();
    assert!(err.0.contains("unknown option"), "{err}");
    assert!(err.0.contains("ksp_type"), "{err}");

    // ...and through run_solve on a raw database too
    let err = api::run_solve(&two_state_builder(), &db(&["-ksp_tpye", "gmres"])).unwrap_err();
    assert!(err.0.contains("ksp_type"), "{err}");
}

/// The CLI rejects unknown keys before solving, with the suggestion.
#[test]
fn cli_unknown_key_is_hard_error() {
    let exe = env!("CARGO_BIN_EXE_madupite");
    let out = std::process::Command::new(exe)
        .args([
            "solve", "-model", "maze", "-rows", "8", "-cols", "8", "-ksp_tpye", "gmres",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown option"), "{stderr}");
    assert!(stderr.contains("did you mean"), "{stderr}");
    assert!(stderr.contains("ksp_type"), "{stderr}");
}

/// Every accepted spelling of -method/-ksp_type/-pc_type/-eval_backend/
/// -objective round-trips to the right enum through the shared resolvers.
#[test]
fn option_spellings_round_trip() {
    use api::options::{resolve_method, resolve_objective, resolve_solve_options};

    assert_eq!(resolve_method(&db(&["-method", "vi"])).unwrap(), Method::Vi);
    assert_eq!(
        resolve_method(&db(&["-method", "mpi", "-sweeps", "12"])).unwrap(),
        Method::Mpi { sweeps: 12 }
    );
    assert_eq!(
        resolve_method(&db(&["-method", "pi"])).unwrap(),
        Method::ExactPi
    );

    let ksp_cases: &[(&str, KspType)] = &[
        ("richardson", KspType::Richardson { omega: 1.0 }),
        ("gmres", KspType::Gmres { restart: 30 }),
        ("bicgstab", KspType::BiCgStab),
        ("bcgs", KspType::BiCgStab),
        ("tfqmr", KspType::Tfqmr),
        ("direct", KspType::Direct),
        ("preonly", KspType::Direct),
    ];
    for (spelling, expect) in ksp_cases {
        let m = resolve_method(&db(&["-method", "ipi", "-ksp_type", *spelling])).unwrap();
        assert_eq!(
            m,
            Method::Ipi {
                ksp: expect.clone(),
                pc: PcType::None
            },
            "-ksp_type {spelling}"
        );
    }

    for (spelling, expect) in [
        ("none", PcType::None),
        ("jacobi", PcType::Jacobi),
        ("sor", PcType::Sor),
    ] {
        let m = resolve_method(&db(&["-pc_type", spelling])).unwrap();
        assert!(
            matches!(m, Method::Ipi { pc, .. } if pc == expect),
            "-pc_type {spelling}"
        );
    }

    for (spelling, expect) in [
        ("matfree", EvalBackend::MatFree),
        ("matrix-free", EvalBackend::MatFree),
        ("mat_free", EvalBackend::MatFree),
        ("assembled", EvalBackend::Assembled),
        ("explicit", EvalBackend::Assembled),
    ] {
        let so = resolve_solve_options(&db(&["-eval_backend", spelling])).unwrap();
        assert_eq!(so.eval_backend, expect, "-eval_backend {spelling}");
    }

    for (spelling, expect) in [
        ("min", Objective::Min),
        ("mincost", Objective::Min),
        ("max", Objective::Max),
        ("maxreward", Objective::Max),
    ] {
        let o = resolve_objective(&db(&["-objective", spelling]), None).unwrap();
        assert_eq!(o, expect, "-objective {spelling}");
    }
}

/// Conflicting and missing sources are typed errors, not panics.
#[test]
fn builder_source_validation() {
    let err = Solver::new(MdpBuilder::new()).solve().unwrap_err();
    assert!(err.0.contains("no model source"), "{err}");

    let both = MdpBuilder::from_file("x.mdpb").fillers(1, 1, |_, _| vec![(0, 1.0)], |_, _| 0.0);
    let err = Solver::new(both).solve().unwrap_err();
    assert!(err.0.contains("conflicting"), "{err}");

    let err = MdpBuilder::from_options(&db(&["-file", "a.mdpb", "-model", "maze"])).unwrap_err();
    assert!(err.0.contains("conflicting"), "{err}");
}

/// Bad gamma surfaces as a validation error from both the builder and the
/// options database.
#[test]
fn bad_gamma_is_error() {
    let err = Solver::new(two_state_builder().gamma(1.5)).solve().unwrap_err();
    assert!(err.0.contains("gamma"), "{err}");

    let mut solver = Solver::new(two_state_builder());
    solver.set_option("-gamma", "2.0").unwrap();
    let err = solver.solve().unwrap_err();
    assert!(err.0.contains("gamma"), "{err}");
}

/// Closure-built MDPs reject non-stochastic rows — serially and across
/// ranks (collective agreement instead of deadlock).
#[test]
fn non_stochastic_closures_rejected() {
    let bad = MdpBuilder::from_fillers(
        24,
        2,
        |s, _| {
            if s == 23 {
                vec![(0, 0.25)] // sub-stochastic row on the last rank
            } else {
                vec![(s, 1.0)]
            }
        },
        |_, _| 1.0,
    )
    .gamma(0.9);
    let err = bad.build_serial().unwrap_err();
    assert!(err.0.contains("sums to"), "{err}");
    for ranks in ["1", "2", "4"] {
        let mut solver = Solver::new(bad.clone());
        solver.set_option("-ranks", ranks).unwrap();
        let err = solver.solve().unwrap_err();
        assert!(err.0.contains("sums to"), "ranks={ranks}: {err}");
    }
}

/// The output surface round-trips: policy/cost/metadata files land on disk
/// with the solved content.
#[test]
fn outputs_round_trip() {
    let mut solver = Solver::new(two_state_builder());
    solver.set_options_from_str("-method ipi -atol 1e-10").unwrap();
    let outcome = solver.solve().unwrap();

    let policy_path = tmpfile("policy.txt");
    let cost_path = tmpfile("cost.txt");
    let meta_path = tmpfile("meta.json");
    outcome.write_policy(&policy_path).unwrap();
    outcome.write_cost(&cost_path).unwrap();
    outcome.write_json_metadata(&meta_path).unwrap();

    let policy_text = std::fs::read_to_string(&policy_path).unwrap();
    let actions: Vec<usize> = policy_text
        .lines()
        .filter(|l| !l.starts_with('#'))
        .map(|l| l.parse().unwrap())
        .collect();
    assert_eq!(actions, outcome.policy());

    let cost_text = std::fs::read_to_string(&cost_path).unwrap();
    let values: Vec<f64> = cost_text
        .lines()
        .filter(|l| !l.starts_with('#'))
        .map(|l| l.parse().unwrap())
        .collect();
    assert_eq!(values.len(), 2);
    assert!((values[0] - 1.5).abs() < 1e-8);

    let meta = Json::parse(&std::fs::read_to_string(&meta_path).unwrap()).unwrap();
    assert_eq!(
        meta.get("model").unwrap().get("n_states").unwrap().as_f64(),
        Some(2.0)
    );
    assert_eq!(
        meta.get("result").unwrap().get("converged").unwrap().as_bool(),
        Some(true)
    );
}

/// Drop the (non-deterministic) wall-time field from a metadata JSON.
fn strip_wall_time(j: &mut Json) {
    if let Some(Json::Obj(result)) = match j {
        Json::Obj(m) => m.get_mut("result"),
        _ => None,
    } {
        result.remove("wall_time_s");
    }
}

/// The CLI and the embedded API resolve the same option set through the
/// same table and produce identical solve metadata (modulo wall time) on a
/// fixed maze — the no-drift guarantee of the shared `run_solve` path.
#[test]
fn cli_api_parity_on_fixed_maze() {
    let args = [
        "-model", "maze", "-rows", "12", "-cols", "12", "-seed", "5", "-gamma", "0.95",
        "-method", "ipi", "-ksp_type", "gmres", "-pc_type", "jacobi", "-atol", "1e-8",
        "-ranks", "2",
    ];

    // CLI side: run the real binary.
    let cli_meta_path = tmpfile("cli_meta.json");
    let cli_policy_path = tmpfile("cli_policy.txt");
    let exe = env!("CARGO_BIN_EXE_madupite");
    let out = std::process::Command::new(exe)
        .arg("solve")
        .args(args)
        .args([
            "-write_json_metadata",
            cli_meta_path.to_str().unwrap(),
            "-write_policy",
            cli_policy_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // API side: same option set through the embedded path.
    let database = db(&args);
    let builder = MdpBuilder::from_options(&database).unwrap();
    let outcome = api::run_solve(&builder, &database).unwrap();
    let api_meta_path = tmpfile("api_meta.json");
    let api_policy_path = tmpfile("api_policy.txt");
    outcome.write_json_metadata(&api_meta_path).unwrap();
    outcome.write_policy(&api_policy_path).unwrap();

    // Policies must be byte-identical; metadata identical modulo wall time.
    let cli_policy = std::fs::read_to_string(&cli_policy_path).unwrap();
    let api_policy = std::fs::read_to_string(&api_policy_path).unwrap();
    assert_eq!(cli_policy, api_policy);

    let mut cli_meta = Json::parse(&std::fs::read_to_string(&cli_meta_path).unwrap()).unwrap();
    let mut api_meta = Json::parse(&std::fs::read_to_string(&api_meta_path).unwrap()).unwrap();
    strip_wall_time(&mut cli_meta);
    strip_wall_time(&mut api_meta);
    assert_eq!(cli_meta.to_string(), api_meta.to_string());
}

/// GMRES restart and Richardson relaxation are reachable from the database.
#[test]
fn ksp_sub_options_resolve() {
    use api::options::resolve_method;
    assert_eq!(
        resolve_method(&db(&["-ksp_type", "gmres", "-ksp_gmres_restart", "7"])).unwrap(),
        Method::Ipi {
            ksp: KspType::Gmres { restart: 7 },
            pc: PcType::None
        }
    );
    assert_eq!(
        resolve_method(&db(&["-ksp_type", "richardson", "-ksp_richardson_scale", "0.5"]))
            .unwrap(),
        Method::Ipi {
            ksp: KspType::Richardson { omega: 0.5 },
            pc: PcType::None
        }
    );
}

/// A distributed closure-defined solve through the options database
/// matches the serial solve of the same model (the api_tour setup).
#[test]
fn closure_model_multi_rank_matches_serial() {
    let builder = || {
        MdpBuilder::from_fillers(
            60,
            2,
            |s, a| {
                let n = 60usize;
                let ps = [0.5, 0.85][a];
                let up = if s + 1 < n { 0.6 * (1.0 - ps) } else { 0.0 };
                let down = if s > 0 { ps * 0.4 } else { 0.0 };
                let mut row = vec![(s, 1.0 - up - down)];
                if s > 0 {
                    row.push((s - 1, down));
                }
                if s + 1 < n {
                    row.push((s + 1, up));
                }
                row.retain(|&(_, p)| p > 0.0);
                row
            },
            |s, a| s as f64 * 0.05 + if a == 1 { 1.0 } else { 0.2 },
        )
        .gamma(0.99)
    };
    let serial = Solver::new(builder()).solve().unwrap();
    let mut dist = Solver::new(builder());
    dist.set_options_from_str("-ranks 4 -method ipi -ksp_type bicgstab")
        .unwrap();
    let dist = dist.solve().unwrap();
    assert!(serial.result.converged && dist.result.converged);
    for (a, b) in serial.value().iter().zip(dist.value()) {
        assert!((a - b).abs() < 1e-6, "{a} vs {b}");
    }
}
