//! Serving-grade integration suite for `madupite::serve` (DESIGN.md §15).
//!
//! - **Catalog acceptance matrix**: for *every* catalog model, querying the
//!   persisted artifact returns the same `(action, value)` per state as the
//!   in-memory `SolveOutcome`, bitwise, under both store backends and cache
//!   sizes {0, 64, unbounded}. The params table is asserted to cover the
//!   whole catalog, so a new model breaks this test loudly.
//! - **Corruption faults**: truncated artifact, flipped version byte,
//!   flipped payload byte, mismatched fingerprint → distinct typed errors;
//!   no panic, and never a silently served stale policy.
//! - **Concurrency soak**: 8 client threads × mixed hit/miss workload,
//!   every response bitwise-equal to a single-threaded oracle, LRU never
//!   exceeds its bound.
//! - **Golden metadata bytes**: `write_json_metadata` emits keys in the
//!   fixed sorted order, byte-for-byte.
//! - **Fingerprint invariance**: execution shape (ranks/threads/overlap)
//!   never changes the serving key; solver tolerances do.
//! - **Binary round-trip**: solve → `-serve_store` → queries through the
//!   `madupite-serve` binary match `write_policy` output exactly.

use madupite::api::{run_solve, MdpBuilder, SolveOutcome, MODEL_CATALOG};
use madupite::comm::OverlapMode;
use madupite::mdp::{DiscountMode, Objective};
use madupite::serve::{
    codec, ArtifactSink, MemorySink, PolicyStore, QueryEngine, ServeError,
};
use madupite::solver::{Method, SolveOptions, SolveResult};
use madupite::util::args::Options;
use madupite::util::json::Json;
use std::sync::Arc;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join("madupite-serve-tests")
        .join(format!("{name}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn solve_with(args: &[&str]) -> SolveOutcome {
    let db = Options::parse(args.iter().map(|s| s.to_string()));
    let builder = MdpBuilder::from_options(&db).unwrap();
    run_solve(&builder, &db).unwrap()
}

/// Small-but-nontrivial parameters for every catalog model. The acceptance
/// matrix asserts this covers the whole catalog.
fn catalog_params(name: &str) -> Option<&'static [&'static str]> {
    Some(match name {
        "maze" | "grid" => &["-rows", "6", "-cols", "6"],
        "sis" => &["-population", "30", "-num_actions", "2"],
        "traffic" => &["-capacity", "4"],
        "garnet" => &["-num_states", "20", "-num_actions", "3", "-branching", "3"],
        "inventory" | "queueing" => &["-capacity", "6"],
        "replacement" | "maintenance" => &["-num_states", "8"],
        _ => return None,
    })
}

/// Assert that serving `outcome` through `store` reproduces it bitwise,
/// twice (cold decode path, then the cached path).
fn assert_roundtrip_exact(store: &PolicyStore, outcome: &SolveOutcome) {
    let fp = store.put_outcome(outcome).unwrap();
    assert_eq!(fp, outcome.fingerprint());
    for _pass in 0..2 {
        let artifact = store.get(&fp).unwrap();
        let engine = QueryEngine::new(artifact);
        for s in 0..outcome.n_states {
            assert_eq!(engine.action(s).unwrap(), outcome.policy()[s]);
            assert_eq!(
                engine.value(s).unwrap().to_bits(),
                outcome.value()[s].to_bits()
            );
        }
        assert!(store.cache_len() <= store.cache_capacity());
    }
}

#[test]
fn catalog_roundtrip_exact_across_backends_and_caches() {
    let dir = tmp("catalog");
    for m in MODEL_CATALOG {
        let params = catalog_params(m.name).unwrap_or_else(|| {
            panic!(
                "catalog model '{}' has no serve-test params — extend catalog_params \
                 so the acceptance matrix keeps covering the whole catalog",
                m.name
            )
        });
        let mut args = vec!["-model", m.name];
        args.extend_from_slice(params);
        let outcome = solve_with(&args);
        for (label, cache) in [("c0", 0usize), ("c64", 64), ("cmax", usize::MAX)] {
            assert_roundtrip_exact(&PolicyStore::in_memory(cache), &outcome);
            let disk = PolicyStore::on_disk(dir.join(format!("{}-{label}", m.name)), cache)
                .unwrap();
            assert_roundtrip_exact(&disk, &outcome);
        }
    }
}

#[test]
fn on_disk_corruption_faults_are_typed() {
    let dir = tmp("corrupt");
    let outcome = solve_with(&["-model", "maze", "-rows", "5", "-cols", "5"]);
    let fp = PolicyStore::on_disk(&dir, 0)
        .unwrap()
        .put_outcome(&outcome)
        .unwrap();
    let path = dir.join(format!("{fp}.mdpa"));
    let clean = std::fs::read(&path).unwrap();

    // Fresh zero-cache store per fault, so every get takes the decode path.
    let fresh = || PolicyStore::on_disk(&dir, 0).unwrap();

    // truncated artifact
    std::fs::write(&path, &clean[..clean.len() / 2]).unwrap();
    match fresh().get(&fp) {
        Err(ServeError::Corrupt(msg)) => {
            assert!(
                msg.contains("truncated") || msg.contains("length mismatch"),
                "{msg}"
            );
        }
        other => panic!("truncation: expected Corrupt, got {other:?}"),
    }

    // flipped version byte
    let mut bad = clean.clone();
    bad[4] ^= 0xFF;
    std::fs::write(&path, &bad).unwrap();
    match fresh().get(&fp) {
        Err(ServeError::BadVersion { found, expected }) => {
            assert_eq!(expected, codec::VERSION);
            assert_ne!(found, codec::VERSION);
        }
        other => panic!("version flip: expected BadVersion, got {other:?}"),
    }

    // flipped payload byte (caught by the embedded digest)
    let mut bad = clean.clone();
    bad[codec::HEADER_LEN + 1] ^= 0x10;
    std::fs::write(&path, &bad).unwrap();
    match fresh().get(&fp) {
        Err(ServeError::Corrupt(msg)) => assert!(msg.contains("digest"), "{msg}"),
        other => panic!("payload flip: expected Corrupt, got {other:?}"),
    }

    // mismatched fingerprint: valid bytes under the wrong key
    std::fs::write(&path, &clean).unwrap();
    let wrong = if fp == "0123456789abcdef" {
        "fedcba9876543210"
    } else {
        "0123456789abcdef"
    };
    std::fs::write(dir.join(format!("{wrong}.mdpa")), &clean).unwrap();
    match fresh().get(wrong) {
        Err(ServeError::FingerprintMismatch { requested, found }) => {
            assert_eq!(requested, wrong);
            assert_eq!(found, fp);
        }
        other => panic!("rename: expected FingerprintMismatch, got {other:?}"),
    }

    // after all faults, the intact artifact still serves
    assert_roundtrip_exact(&fresh(), &outcome);
}

#[test]
fn memory_sink_corruption_faults_are_typed() {
    // Same faults through the injected in-memory sink — both backends run
    // the one codec, so the typed errors must be identical in kind.
    let outcome = solve_with(&["-model", "grid", "-rows", "5", "-cols", "5"]);
    let artifact = madupite::serve::PolicyArtifact::from_outcome(&outcome);
    let fp = artifact.fingerprint_hex();
    let clean = artifact.encode();

    let with_bytes = |bytes: &[u8]| {
        let sink = MemorySink::new();
        sink.put(&fp, bytes).unwrap();
        PolicyStore::with_sink(Box::new(sink), 0)
    };

    assert!(matches!(
        with_bytes(&clean[..codec::HEADER_LEN - 1]).get(&fp),
        Err(ServeError::Corrupt(_))
    ));
    let mut bad = clean.clone();
    bad[4] ^= 0x01;
    assert!(matches!(
        with_bytes(&bad).get(&fp),
        Err(ServeError::BadVersion { .. })
    ));
    let mut bad = clean.clone();
    *bad.last_mut().unwrap() ^= 0x01; // inside the meta document
    assert!(matches!(
        with_bytes(&bad).get(&fp),
        Err(ServeError::Corrupt(_))
    ));
    assert!(matches!(
        with_bytes(&clean).get("ffffffffffffffff"),
        Err(ServeError::NotFound(_))
    ));
}

#[test]
fn concurrency_soak_bitwise_oracle_and_cache_bound() {
    let o1 = solve_with(&["-model", "maze", "-rows", "6", "-cols", "6"]);
    let o2 = solve_with(&["-model", "grid", "-rows", "6", "-cols", "6"]);
    let dir = tmp("soak");
    // cache capacity 1 with two hot artifacts: constant churn, both the
    // hit and the miss+decode paths run under contention.
    let store = Arc::new(PolicyStore::on_disk(&dir, 1).unwrap());
    let fp1 = store.put_outcome(&o1).unwrap();
    let fp2 = store.put_outcome(&o2).unwrap();
    assert_ne!(fp1, "ffffffffffffffff");
    assert_ne!(fp2, "ffffffffffffffff");

    // single-threaded oracle: full response tables per artifact
    let oracle = |fp: &str| -> (Vec<usize>, Vec<u64>) {
        let engine = QueryEngine::new(store.get(fp).unwrap());
        let n = engine.artifact().n_states;
        (
            (0..n).map(|s| engine.action(s).unwrap()).collect(),
            (0..n).map(|s| engine.value(s).unwrap().to_bits()).collect(),
        )
    };
    let oracle1 = oracle(&fp1);
    let oracle2 = oracle(&fp2);

    std::thread::scope(|scope| {
        for t in 0..8usize {
            let store = Arc::clone(&store);
            let (fp1, fp2) = (&fp1, &fp2);
            let (oracle1, oracle2) = (&oracle1, &oracle2);
            scope.spawn(move || {
                let mut x: u64 = 0x9e3779b97f4a7c15 ^ (t as u64);
                for i in 0..2_000usize {
                    if i % 97 == 13 {
                        // miss workload: absent fingerprints are typed
                        assert!(matches!(
                            store.get("ffffffffffffffff"),
                            Err(ServeError::NotFound(_))
                        ));
                    }
                    let (fp, (actions, value_bits)) = if (t + i) % 2 == 0 {
                        (fp1, oracle1)
                    } else {
                        (fp2, oracle2)
                    };
                    let engine = QueryEngine::new(store.get(fp).unwrap());
                    x = x
                        .wrapping_mul(6364136223846793005)
                        .wrapping_add(1442695040888963407);
                    let s = (x % engine.artifact().n_states as u64) as usize;
                    assert_eq!(engine.action(s).unwrap(), actions[s]);
                    assert_eq!(engine.value(s).unwrap().to_bits(), value_bits[s]);
                    assert!(store.cache_len() <= store.cache_capacity());
                }
            });
        }
    });
    assert!(store.cache_len() <= store.cache_capacity());
}

/// Hand-built outcome with dyadic floats (0.5, 0.25, 0.125 — exact in
/// `f64` Display), so the expected bytes below are straightforward.
fn synthetic_outcome() -> SolveOutcome {
    SolveOutcome {
        n_states: 2,
        n_actions: 2,
        gamma: 0.5,
        discount_mode: DiscountMode::Scalar,
        objective: Objective::Min,
        options: SolveOptions {
            method: Method::Vi,
            atol: 0.25,
            alpha: 0.125,
            ..SolveOptions::default()
        },
        ranks: 1,
        threads: 1,
        comm_overlap: OverlapMode::Off,
        warm_start: None,
        result: SolveResult {
            value: vec![1.5, 0.25],
            policy: vec![1, 0],
            outer_iterations: 3,
            total_spmvs: 7,
            total_inner_iterations: 5,
            residual: 0.25,
            converged: true,
            wall_time_s: 0.25,
            trace: vec![],
            comm_bytes: 64,
            comm_time_us: 12,
            gamma: 0.5,
            ranks: 1,
            threads: 1,
        },
    }
}

#[test]
fn write_json_metadata_golden_bytes() {
    // Keys serialize sorted at every nesting level (BTreeMap objects), so
    // the emitted bytes are pinned exactly. If this test fails, the
    // metadata layout changed — that is a breaking change for downstream
    // parsers and must be deliberate.
    let outcome = synthetic_outcome();
    let path = tmp("golden").join("meta.json");
    outcome.write_json_metadata(&path).unwrap();
    let got = std::fs::read_to_string(&path).unwrap();
    let expected = format!(
        r#"{{
  "madupite_version": "{version}",
  "model": {{
    "discount_mode": "scalar",
    "gamma": 0.5,
    "n_actions": 2,
    "n_states": 2,
    "objective": "min"
  }},
  "result": {{
    "comm_bytes": 64,
    "comm_time_us": 12,
    "converged": true,
    "error_bound": 0.5,
    "label": "vi",
    "outer_iterations": 3,
    "ranks": 1,
    "residual": 0.25,
    "residual_trace": [],
    "threads": 1,
    "total_inner_iterations": 5,
    "total_spmvs": 7,
    "wall_time_s": 0.25
  }},
  "solver": {{
    "adaptive_forcing": false,
    "alpha": 0.125,
    "async_vi": false,
    "async_vi_staleness": 4,
    "atol": 0.25,
    "comm_overlap": "off",
    "eval_backend": "matfree",
    "inner_precision": "f64",
    "max_iter_ksp": 10000,
    "max_iter_pi": 1000,
    "method": "vi",
    "ranks": 1,
    "threads": 1
  }}
}}
"#,
        version = madupite::VERSION
    );
    assert_eq!(got, expected);
}

#[test]
fn fingerprint_doc_is_canonical_and_excludes_execution_shape() {
    let outcome = synthetic_outcome();
    let compact = outcome.fingerprint_json().to_string();
    // sorted top-level key order of the canonical document
    assert!(compact.starts_with(r#"{"format":"madupite-artifact-fp/v1","model":{"#));
    let i_model = compact.find("\"model\"").unwrap();
    let i_policy = compact.find("\"policy_digest\"").unwrap();
    let i_solver = compact.find("\"solver\"").unwrap();
    let i_value = compact.find("\"value_digest\"").unwrap();
    assert!(i_model < i_policy && i_policy < i_solver && i_solver < i_value);
    // the execution shape must not appear anywhere in the document
    for excluded in ["ranks", "threads", "comm_overlap", "async_vi"] {
        assert!(!compact.contains(excluded), "{excluded} leaked into {compact}");
    }

    // execution shape never changes the key …
    let mut shaped = synthetic_outcome();
    shaped.ranks = 4;
    shaped.threads = 8;
    shaped.comm_overlap = OverlapMode::On;
    assert_eq!(outcome.fingerprint(), shaped.fingerprint());
    // … while solver tolerances and payloads do
    let mut tighter = synthetic_outcome();
    tighter.options.atol = 0.125;
    assert_ne!(outcome.fingerprint(), tighter.fingerprint());
    let mut other_value = synthetic_outcome();
    other_value.result.value[0] = 1.75;
    assert_ne!(outcome.fingerprint(), other_value.fingerprint());
}

#[test]
fn solved_fingerprint_is_rank_invariant() {
    let base = solve_with(&["-model", "maze", "-rows", "5", "-cols", "5"]);
    let dist = solve_with(&[
        "-model", "maze", "-rows", "5", "-cols", "5", "-ranks", "2", "-threads", "2",
        "-comm_overlap", "on",
    ]);
    assert_eq!(base.fingerprint(), dist.fingerprint());
    let looser = solve_with(&["-model", "maze", "-rows", "5", "-cols", "5", "-atol", "1e-4"]);
    assert_ne!(base.fingerprint(), looser.fingerprint());
}

#[test]
fn serve_binary_roundtrip_matches_write_policy() {
    use std::io::Write as _;
    let dir = tmp("bin");
    let store_dir = dir.join("store");
    let policy_path = dir.join("pi.txt");
    let outcome = solve_with(&[
        "-model",
        "maze",
        "-rows",
        "6",
        "-cols",
        "6",
        "-serve_store",
        store_dir.to_str().unwrap(),
        "-write_policy",
        policy_path.to_str().unwrap(),
    ]);
    let fp = outcome.fingerprint();
    let n = outcome.n_states;

    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_madupite-serve"))
        .args([
            "-serve_store",
            store_dir.to_str().unwrap(),
            "-serve_threads",
            "2",
        ])
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .unwrap();
    let states = (0..n)
        .map(|s| s.to_string())
        .collect::<Vec<_>>()
        .join(", ");
    {
        let mut stdin = child.stdin.take().unwrap();
        writeln!(stdin, r#"{{"id": 1, "op": "list"}}"#).unwrap();
        writeln!(
            stdin,
            r#"{{"id": 2, "op": "action", "fingerprint": "{fp}", "states": [{states}]}}"#
        )
        .unwrap();
        writeln!(
            stdin,
            r#"{{"id": 3, "op": "value", "fingerprint": "{fp}", "states": [{states}]}}"#
        )
        .unwrap();
    } // dropping stdin closes the pipe, the server loop ends
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success());
    let lines: Vec<&str> = std::str::from_utf8(&out.stdout).unwrap().lines().collect();
    assert_eq!(lines.len(), 3, "{lines:?}");

    let list = Json::parse(lines[0]).unwrap();
    assert_eq!(list.get("ok").and_then(Json::as_bool), Some(true));
    assert!(list
        .get("results")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .any(|k| k.as_str() == Some(fp.as_str())));

    // actions: protocol response == in-memory outcome == write_policy file
    let actions = Json::parse(lines[1]).unwrap();
    assert_eq!(actions.get("ok").and_then(Json::as_bool), Some(true));
    let served: Vec<usize> = actions
        .get("results")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as usize)
        .collect();
    assert_eq!(served, outcome.policy());
    let file_actions: Vec<usize> = std::fs::read_to_string(&policy_path)
        .unwrap()
        .lines()
        .skip(1) // '#' header
        .map(|l| l.trim().parse().unwrap())
        .collect();
    assert_eq!(served, file_actions);

    // values: JSON f64 round-trip is exact (shortest-repr Display), so the
    // served numbers are bitwise the solver's
    let values = Json::parse(lines[2]).unwrap();
    assert_eq!(values.get("ok").and_then(Json::as_bool), Some(true));
    let served: Vec<f64> = values
        .get("results")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap())
        .collect();
    assert_eq!(served.len(), n);
    for (a, b) in served.iter().zip(outcome.value()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
