//! Offline pipeline end-to-end tests (paper claim C5, `.mdpb` v2).
//!
//! The acceptance properties of the v2 format + streaming writer:
//! - an MDP saved with `Objective::Max` reloads as max-objective and
//!   solves to the same values/policy as the in-memory model, through
//!   both the serial and the rank-sliced distributed reader;
//! - rank-parallel streaming generation (`write_mdpb`) produces bytes
//!   identical to the in-memory save, for every world size, and the
//!   resulting file solves identically to the in-memory model — i.e.
//!   "collect on M ranks, solve on N" holds across the full matrix.

use madupite::comm::World;
use madupite::mdp::{io, Objective};
use madupite::models::{garnet::GarnetSpec, ModelGenerator};
use madupite::solver::{gather_result, solve_dist, solve_serial, Method, SolveOptions};
use std::sync::Arc;

fn tmpfile(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("madupite-io-pipeline");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert!(
            (a[i] - b[i]).abs() <= tol * (1.0 + a[i].abs().max(b[i].abs())),
            "{what}: element {i}: {} vs {}",
            a[i],
            b[i]
        );
    }
}

/// The v1 regression end-to-end: a reward-maximizing MDP must round-trip
/// as reward-maximizing. Before v2 the objective was dropped on save and
/// hard-coded to Min on load, so the reloaded model solved to the
/// *cost-minimizing* policy.
#[test]
fn max_objective_roundtrips_and_solves_identically() {
    let mdp = GarnetSpec::new(60, 3, 4, 7)
        .build_serial(0.95)
        .with_objective(Objective::Max);
    let opts = SolveOptions {
        method: Method::ipi_gmres(),
        atol: 1e-9,
        ..Default::default()
    };
    let want = solve_serial(&mdp, &opts);
    assert!(want.converged);

    // sanity: the max policy genuinely differs from the min policy, so
    // this test would catch an objective silently degrading to Min
    let min_res = solve_serial(&mdp.clone().with_objective(Objective::Min), &opts);
    assert_ne!(want.policy, min_res.policy, "degenerate fixture");

    let path = tmpfile("pipeline_max.mdpb");
    io::save(&mdp, &path).unwrap();

    // serial reload
    let loaded = io::load(&path).unwrap();
    assert_eq!(loaded.objective(), Objective::Max);
    let got = solve_serial(&loaded, &opts);
    assert!(got.converged);
    close(&want.value, &got.value, 1e-7, "serial reload values");
    assert_eq!(want.policy, got.policy, "serial reload policy");

    // distributed reload on several world sizes
    for ranks in [1usize, 2, 3] {
        let p = path.clone();
        let o = opts.clone();
        let mut results = World::run(ranks, move |comm| {
            let d = io::load_dist(&comm, &p).unwrap();
            assert_eq!(d.objective(), Objective::Max);
            gather_result(&comm, solve_dist(&comm, &d, &o))
        });
        let r = results.swap_remove(0);
        assert!(r.converged, "ranks={ranks}");
        close(
            &want.value,
            &r.value,
            1e-7,
            &format!("dist reload values (ranks={ranks})"),
        );
        assert_eq!(want.policy, r.policy, "dist reload policy (ranks={ranks})");
    }
}

/// Generate on M ranks (streaming, O(chunk) memory), solve on N ranks:
/// the full offline matrix must agree with solving the in-memory model.
#[test]
fn streaming_generate_on_m_ranks_solve_on_n_ranks() {
    let spec = Arc::new(GarnetSpec::new(80, 3, 5, 21));
    let gamma = 0.97;
    let mdp = spec.build_serial(gamma).with_objective(Objective::Max);
    let opts = SolveOptions {
        method: Method::ipi_gmres(),
        atol: 1e-9,
        ..Default::default()
    };
    let want = solve_serial(&mdp, &opts);
    assert!(want.converged);

    for gen_ranks in [1usize, 3] {
        let path = tmpfile(&format!("gen_m{gen_ranks}.mdpb"));
        let spec2 = Arc::clone(&spec);
        let p = path.clone();
        let results = World::run(gen_ranks, move |comm| {
            // small chunk to exercise many flushes
            spec2.write_mdpb(&comm, gamma, Objective::Max, &p, 13)
        });
        for r in results {
            r.unwrap();
        }
        for solve_ranks in [1usize, 2] {
            let p = path.clone();
            let o = opts.clone();
            let mut results = World::run(solve_ranks, move |comm| {
                let d = io::load_dist(&comm, &p).unwrap();
                gather_result(&comm, solve_dist(&comm, &d, &o))
            });
            let r = results.swap_remove(0);
            assert!(r.converged, "gen={gen_ranks} solve={solve_ranks}");
            close(
                &want.value,
                &r.value,
                1e-7,
                &format!("values (gen={gen_ranks}, solve={solve_ranks})"),
            );
            assert_eq!(
                want.policy, r.policy,
                "policy (gen={gen_ranks}, solve={solve_ranks})"
            );
        }
    }
}

/// `info`-level sanity on a streamed file: the header round-trips the
/// generation parameters exactly.
#[test]
fn streamed_header_reports_generation_parameters() {
    let spec = GarnetSpec::new(50, 2, 3, 5);
    let path = tmpfile("header_check.mdpb");
    let p = path.clone();
    let nnz = {
        let spec = Arc::new(spec);
        let s2 = Arc::clone(&spec);
        let mut out = World::run(2, move |comm| {
            s2.write_mdpb(&comm, 0.9, Objective::Max, &p, io::DEFAULT_CHUNK_ROWS)
                .unwrap()
        });
        out.swap_remove(0).nnz
    };
    let mut f = std::fs::File::open(&path).unwrap();
    let file_len = f.metadata().unwrap().len();
    let h = io::read_header(&mut f).unwrap();
    h.validate_file_len(file_len).unwrap();
    assert_eq!(h.version, io::VERSION);
    assert_eq!(h.n_states, 50);
    assert_eq!(h.n_actions, 2);
    assert_eq!(h.gamma, 0.9);
    assert_eq!(h.objective, Objective::Max);
    assert_eq!(h.nnz, nnz);
}
