//! Edge cases and failure injection across the public API.

use madupite::comm::{Comm, World};
use madupite::ksp::KspType;
use madupite::linalg::dist::Partition;
use madupite::linalg::Csr;
use madupite::mdp::{io, Mdp};
use madupite::models::{garnet::GarnetSpec, gridworld::GridSpec, ModelGenerator};
use madupite::solver::{solve_serial, Method, SolveOptions};
use madupite::util::json::Json;
use madupite::util::prng::Xoshiro256pp;

fn tmpfile(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("madupite-edge");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

// ---------------------------------------------------------------- MDP edges

/// Single-state, single-action MDP: V* = g/(1−γ) exactly.
#[test]
fn degenerate_single_state() {
    let mdp = Mdp::from_fillers(1, 1, 0.5, |_, _| vec![(0, 1.0)], |_, _| 3.0);
    for method in [Method::Vi, Method::ExactPi, Method::ipi_gmres()] {
        let r = solve_serial(
            &mdp,
            &SolveOptions {
                method,
                atol: 1e-12,
                max_outer: 10_000,
                ..Default::default()
            },
        );
        assert!(r.converged);
        assert!((r.value[0] - 6.0).abs() < 1e-7, "V={}", r.value[0]);
    }
}

/// γ = 0 reduces the MDP to one-step cost minimization.
#[test]
fn gamma_zero_is_myopic() {
    let mdp = GarnetSpec::new(30, 4, 3, 9).build_serial(0.0);
    let r = solve_serial(&mdp, &SolveOptions::default());
    assert!(r.converged);
    // one productive iteration + one verifying backup that certifies
    // convergence
    assert!(r.outer_iterations <= 2, "{}", r.outer_iterations);
    for s in 0..30 {
        let min_cost = (0..4).map(|a| mdp.cost(s, a)).fold(f64::INFINITY, f64::min);
        assert!((r.value[s] - min_cost).abs() < 1e-12);
    }
}

/// All-identical actions: every policy is optimal; solver must not cycle.
#[test]
fn identical_actions_tie_break() {
    let mdp = Mdp::from_fillers(
        10,
        3,
        0.9,
        |s, _| vec![((s + 1) % 10, 1.0)],
        |_, _| 1.0,
    );
    let r = solve_serial(&mdp, &SolveOptions::default());
    assert!(r.converged);
    // V = 1/(1−γ) = 10 everywhere, policy all zeros by first-wins tie-break
    for s in 0..10 {
        assert!((r.value[s] - 10.0).abs() < 1e-6);
        assert_eq!(r.policy[s], 0);
    }
}

/// Costs may be negative (rewards); discounted sum still converges.
#[test]
fn negative_costs_supported() {
    let mdp = Mdp::from_fillers(
        2,
        2,
        0.5,
        |_, _| vec![(0, 0.5), (1, 0.5)],
        |s, a| if (s, a) == (0, 1) { -2.0 } else { 1.0 },
    );
    let r = solve_serial(
        &mdp,
        &SolveOptions {
            atol: 1e-10,
            ..Default::default()
        },
    );
    assert!(r.converged);
    assert_eq!(r.policy[0], 1);
    assert!(r.value[0] < 0.0);
}

/// Very high discount (0.99999) with exact PI stays stable.
#[test]
fn extreme_discount_exact_pi() {
    let mdp = GarnetSpec::new(25, 3, 3, 4).build_serial(0.99999);
    let r = solve_serial(
        &mdp,
        &SolveOptions {
            method: Method::ExactPi,
            atol: 1e-6,
            ..Default::default()
        },
    );
    assert!(r.converged);
    assert!(r.outer_iterations < 60, "PI should terminate in few steps");
    assert!(r.value.iter().all(|v| v.is_finite()));
}

// ------------------------------------------------------------ IO failure injection

#[test]
fn truncated_file_rejected_cleanly() {
    let mdp = GarnetSpec::new(20, 2, 3, 1).build_serial(0.9);
    let path = tmpfile("trunc.mdpb");
    io::save(&mdp, &path).unwrap();
    let full = std::fs::read(&path).unwrap();
    // cut the file at several points: header, indptr, payload
    for cut in [3usize, 20, 60, full.len() - 9] {
        let p = tmpfile(&format!("trunc_{cut}.mdpb"));
        std::fs::write(&p, &full[..cut]).unwrap();
        assert!(io::load(&p).is_err(), "cut at {cut} must fail");
    }
}

#[test]
fn corrupted_gamma_rejected() {
    let mdp = GarnetSpec::new(10, 2, 2, 1).build_serial(0.9);
    let path = tmpfile("badgamma.mdpb");
    io::save(&mdp, &path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[24..32].copy_from_slice(&2.5f64.to_le_bytes()); // gamma = 2.5
    std::fs::write(&path, &bytes).unwrap();
    assert!(io::load(&path).is_err());
}

#[test]
fn nonstochastic_file_rejected() {
    let mdp = GarnetSpec::new(10, 2, 2, 1).build_serial(0.9);
    let path = tmpfile("nonstoch.mdpb");
    io::save(&mdp, &path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    // values start after the v2 header + indptr + indices
    let nm = 20usize;
    let nnz = mdp.transitions().nnz();
    let values_off = 48 + 8 * (nm + 1) + 8 * nnz;
    bytes[values_off..values_off + 8].copy_from_slice(&9.0f64.to_le_bytes());
    std::fs::write(&path, &bytes).unwrap();
    assert!(io::load(&path).is_err());
    // the distributed reader applies the same stochasticity validation
    World::run(2, move |comm| {
        assert!(io::load_dist(&comm, &path).is_err());
    });
}

// ------------------------------------------------------------ comm stress

/// Many interleaved collectives under contention (4 ranks × 200 epochs).
#[test]
fn collective_storm_consistent() {
    let out = World::run(4, |comm: Comm| {
        let mut acc = 0.0;
        for i in 0..200 {
            let x = (comm.rank() + i) as f64;
            acc += comm.sum(x);
            if i % 3 == 0 {
                let v = comm.allgather_f64s(&[comm.rank() as f64]);
                assert_eq!(v, vec![0.0, 1.0, 2.0, 3.0]);
            }
            if i % 7 == 0 {
                comm.barrier();
            }
        }
        acc
    });
    // sum over ranks of (rank + i) for each i: Σ_i (6 + 4i)
    let expect: f64 = (0..200).map(|i| 6.0 + 4.0 * i as f64).sum();
    for v in out {
        assert_eq!(v, expect);
    }
}

/// Tag-heavy p2p traffic delivered in-order per (source, tag).
#[test]
fn p2p_ordering_per_tag() {
    World::run(2, |mut comm: Comm| {
        if comm.rank() == 0 {
            for i in 0..50u64 {
                comm.send(1, i % 5, vec![i as u8]);
            }
        } else {
            // receive per tag: order within a tag must be preserved
            for tag in 0..5u64 {
                let mut last = -1i32;
                for _ in 0..10 {
                    let b = comm.recv(0, tag);
                    assert!((b[0] as i32) > last);
                    last = b[0] as i32;
                }
            }
        }
    });
}

/// Partition handles n < size (some ranks own zero states).
#[test]
fn more_ranks_than_states() {
    let part = Partition::new(3, 5);
    let total: usize = (0..5).map(|r| part.local_len(r)).sum();
    assert_eq!(total, 3);
    // solving still works with empty ranks
    let spec = std::sync::Arc::new(GarnetSpec::new(3, 2, 2, 8));
    let out = World::run(5, move |comm| {
        let mdp = spec.build_dist(&comm, 0.9);
        let local = madupite::solver::solve_dist(&comm, &mdp, &SolveOptions::default());
        madupite::solver::gather_result(&comm, local)
    });
    assert!(out[0].converged);
    assert_eq!(out[0].value.len(), 3);
}

// ------------------------------------------------------------ ksp edges

/// Inner solvers handle b = 0 → x = 0 without iterating.
#[test]
fn zero_cost_policy_evaluates_to_zero() {
    let mdp = Mdp::from_fillers(8, 1, 0.9, |s, _| vec![((s + 1) % 8, 1.0)], |_, _| 0.0);
    for ksp in [
        KspType::Richardson { omega: 1.0 },
        KspType::Gmres { restart: 10 },
        KspType::BiCgStab,
        KspType::Tfqmr,
    ] {
        let r = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::Ipi {
                    ksp,
                    pc: madupite::ksp::precond::PcType::None,
                },
                atol: 1e-10,
                ..Default::default()
            },
        );
        assert!(r.converged);
        assert!(r.value.iter().all(|v| v.abs() < 1e-9));
    }
}

// ------------------------------------------------------------ json fuzz-lite

#[test]
fn json_random_roundtrip() {
    let mut rng = Xoshiro256pp::new(123);
    for _ in 0..200 {
        let v = random_json(&mut rng, 3);
        let s = v.to_string();
        let back = Json::parse(&s).unwrap_or_else(|e| panic!("{s}: {e}"));
        assert_eq!(back, v, "{s}");
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }
}

fn random_json(rng: &mut Xoshiro256pp, depth: usize) -> Json {
    match if depth == 0 { rng.index(4) } else { rng.index(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.next_f64() < 0.5),
        2 => Json::Num((rng.next_f64() * 1e6).round() / 1e3),
        3 => Json::Str(
            (0..rng.index(12))
                .map(|_| char::from(32 + rng.index(90) as u8))
                .collect(),
        ),
        4 => Json::Arr((0..rng.index(4)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.index(4))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

// ------------------------------------------------------------ maze robustness

/// Tiny mazes (below the divider's minimum chamber) are valid MDPs.
#[test]
fn tiny_mazes_valid() {
    for (r, c) in [(2usize, 2usize), (2, 5), (3, 3), (4, 2)] {
        let spec = GridSpec::maze(r, c, 1);
        let mdp = spec.build_serial(0.9);
        let res = solve_serial(&mdp, &SolveOptions::default());
        assert!(res.converged, "{r}x{c}");
    }
}

/// CLI rejects unknown models/methods with an error, not a panic.
#[test]
fn cli_rejects_bad_input() {
    let exe = env!("CARGO_BIN_EXE_madupite");
    let out = std::process::Command::new(exe)
        .args(["solve", "-model", "doesnotexist"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown model"));

    let out = std::process::Command::new(exe)
        .args(["solve", "-model", "maze", "-rows", "8", "-cols", "8", "-method", "bogus"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}
