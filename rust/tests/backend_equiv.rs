//! Evaluation-backend equivalence: the fused matrix-free policy operator,
//! the assembled `P_π` CSR, and the lane-blocked BSR backend must be
//! *indistinguishable* through the public API — same values, same
//! policies, for every bundled model family and every outer method,
//! serial and distributed — and the `f32` inner-precision mode must reach
//! the same f64 outer certificate.

use madupite::comm::World;
use madupite::ksp::precond::PcType;
use madupite::ksp::{Apply, KspType, LinOp};
use madupite::mdp::{DistMdp, MatFreePolicyOp};
use madupite::models::{
    garnet::GarnetSpec, gridworld::GridSpec, inventory::InventorySpec, queueing::QueueSpec,
    replacement::ReplacementSpec, sis::SisSpec, traffic::TrafficSpec, ModelGenerator,
};
use madupite::solver::{
    gather_result, solve_dist, solve_serial, EvalBackend, InnerPrecision, Method, SolveOptions,
};
use madupite::util::prng::Xoshiro256pp;
use std::sync::Arc;

fn close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert!(
            (a[i] - b[i]).abs() <= tol * (1.0 + a[i].abs().max(b[i].abs())),
            "{what}: element {i}: {} vs {}",
            a[i],
            b[i]
        );
    }
}

fn models() -> Vec<(&'static str, Box<dyn ModelGenerator>, f64)> {
    vec![
        ("maze", Box::new(GridSpec::maze(8, 8, 3)), 0.95),
        ("grid", Box::new(GridSpec::open(6, 7)), 0.9),
        ("sis", Box::new(SisSpec::standard(30, 3)), 0.95),
        ("traffic", Box::new(TrafficSpec::standard(4)), 0.95),
        ("garnet", Box::new(GarnetSpec::new(40, 3, 4, 7)), 0.95),
        ("inventory", Box::new(InventorySpec::standard(8)), 0.95),
        ("queueing", Box::new(QueueSpec::standard(8)), 0.95),
        ("replacement", Box::new(ReplacementSpec::standard(12)), 0.9),
    ]
}

fn methods() -> Vec<Method> {
    vec![
        Method::Vi,
        Method::Mpi { sweeps: 10 },
        Method::ExactPi,
        Method::ipi_gmres(),
        Method::ipi_bicgstab(),
        Method::ipi_tfqmr(),
        Method::Ipi {
            ksp: KspType::Richardson { omega: 1.0 },
            pc: PcType::Jacobi,
        },
        Method::Ipi {
            ksp: KspType::Gmres { restart: 15 },
            pc: PcType::Sor,
        },
    ]
}

/// The headline property: per model × per method, the matrix-free and
/// assembled backends produce identical values and policies within atol.
#[test]
fn backends_identical_per_model_per_method() {
    let atol = 1e-9;
    for (name, gen, gamma) in &models() {
        let mdp = gen.build_serial(*gamma);
        for method in &methods() {
            let mut values: Vec<Vec<f64>> = Vec::new();
            let mut policies: Vec<Vec<usize>> = Vec::new();
            for backend in [
                EvalBackend::MatFree,
                EvalBackend::Assembled,
                EvalBackend::Bsr,
            ] {
                let r = solve_serial(
                    &mdp,
                    &SolveOptions {
                        method: method.clone(),
                        eval_backend: backend,
                        atol,
                        max_outer: 100_000,
                        ..Default::default()
                    },
                );
                assert!(
                    r.converged,
                    "{name}/{}/{} did not converge",
                    method.name(),
                    backend.name()
                );
                assert!(
                    r.residual < atol,
                    "{name}/{}/{}: residual {}",
                    method.name(),
                    backend.name(),
                    r.residual
                );
                values.push(r.value);
                policies.push(r.policy);
            }
            for (k, v) in values.iter().enumerate().skip(1) {
                close(
                    &values[0],
                    v,
                    1e-7,
                    &format!("{name}/{} backend #{k}", method.name()),
                );
            }
            for p in &policies[1..] {
                assert_eq!(
                    &policies[0],
                    p,
                    "{name}/{}: greedy policies differ between backends",
                    method.name()
                );
            }
        }
    }
}

/// Backend invariance must also hold distributed (the matrix-free ghost
/// exchange goes through the stacked plan, the assembled one through a
/// fresh P_π plan — results must not care).
#[test]
fn backends_identical_distributed() {
    let spec = Arc::new(GarnetSpec::new(120, 3, 5, 13));
    let mut reference: Option<Vec<f64>> = None;
    for ranks in [1usize, 3] {
        for backend in [
            EvalBackend::MatFree,
            EvalBackend::Assembled,
            EvalBackend::Bsr,
        ] {
            let spec2 = Arc::clone(&spec);
            let opts = SolveOptions {
                method: Method::ipi_gmres(),
                eval_backend: backend,
                atol: 1e-9,
                ..Default::default()
            };
            let mut out = World::run(ranks, move |comm| {
                let mdp = spec2.build_dist(&comm, 0.97);
                let local = solve_dist(&comm, &mdp, &opts);
                gather_result(&comm, local)
            });
            let r = out.swap_remove(0);
            assert!(r.converged, "ranks={ranks} {}", backend.name());
            match &reference {
                None => reference = Some(r.value),
                Some(v) => close(
                    v,
                    &r.value,
                    1e-7,
                    &format!("ranks={ranks}/{}", backend.name()),
                ),
            }
        }
    }
}

/// Raw operator equivalence across the public API: MatFreePolicyOp::apply
/// must match LinOp::apply over the assembled P_π for random policies on
/// every model family, serial and on 3 ranks.
#[test]
fn matfree_apply_equals_assembled_apply_random_policies() {
    for (name, gen, gamma) in &models() {
        let mdp = Arc::new(gen.build_serial(*gamma));
        for (ranks, seed) in [(1usize, 5u64), (3, 6)] {
            let mdp2 = Arc::clone(&mdp);
            let name2 = name.to_string();
            World::run(ranks, move |comm| {
                let d = DistMdp::from_serial(&comm, &mdp2);
                let part = d.partition();
                let (lo, hi) = (part.lo(comm.rank()), part.hi(comm.rank()));
                let nl = hi - lo;
                let m = d.n_actions();
                let policy: Vec<usize> = (lo..hi)
                    .map(|s| {
                        let mut rng = Xoshiro256pp::new(seed ^ (s as u64).wrapping_mul(0x9E37));
                        rng.index(m)
                    })
                    .collect();
                let x: Vec<f64> = (lo..hi).map(|i| (i as f64 * 0.13).sin()).collect();

                let (p_pi, g_asm) = d.policy_system(&comm, &policy);
                let asm = LinOp::new(&p_pi, d.gamma());
                let mf = MatFreePolicyOp::new(&d, &policy);

                let mut y_asm = vec![0.0; nl];
                let mut y_mf = vec![0.0; nl];
                let mut buf_a = asm.make_buffer();
                let mut buf_m = mf.make_buffer();
                asm.apply(&comm, &x, &mut y_asm, &mut buf_a);
                mf.apply(&comm, &x, &mut y_mf, &mut buf_m);
                for i in 0..nl {
                    assert!(
                        (y_asm[i] - y_mf[i]).abs() < 1e-12,
                        "{name2} ranks={}: apply[{i}]: {} vs {}",
                        part.size(),
                        y_asm[i],
                        y_mf[i]
                    );
                }

                // RHS agrees too
                let g_mf = d.policy_costs(&policy);
                assert_eq!(g_asm, g_mf, "{name2}: g_pi differs");
            });
        }
    }
}

/// Mixed-precision inner solves (`-inner_precision f32`) must reach the
/// same f64 outer certificate as full-precision runs on every bundled
/// model family — the refinement loop certifies against the f64 operator,
/// so the outer residual is a real f64 Bellman residual, not an f32 one.
#[test]
fn f32_inner_matches_f64_on_catalog() {
    let atol = 1e-9;
    for (name, gen, gamma) in &models() {
        let mdp = gen.build_serial(*gamma);
        let base = SolveOptions {
            method: Method::ipi_gmres(),
            atol,
            max_outer: 100_000,
            ..Default::default()
        };
        let r64 = solve_serial(&mdp, &base);
        let r32 = solve_serial(
            &mdp,
            &SolveOptions {
                inner_precision: InnerPrecision::F32,
                ..base
            },
        );
        assert!(r32.converged, "{name}: f32-inner did not converge");
        assert!(
            r32.residual < atol,
            "{name}: f32-inner residual {}",
            r32.residual
        );
        close(&r64.value, &r32.value, 1e-7, &format!("{name}: f32 vs f64"));
        assert_eq!(r64.policy, r32.policy, "{name}: policies differ");
    }
}

/// Regression (satellite fix): adaptive forcing with alpha > 0.1 used to
/// panic inside `f64::clamp`; it must now solve normally through both
/// backends.
#[test]
fn adaptive_forcing_large_alpha_regression() {
    let mdp = GarnetSpec::new(60, 3, 4, 11).build_serial(0.98);
    for backend in [EvalBackend::MatFree, EvalBackend::Assembled] {
        let r = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::ipi_gmres(),
                eval_backend: backend,
                alpha: 0.5,
                adaptive_forcing: true,
                atol: 1e-8,
                max_outer: 100_000,
                ..Default::default()
            },
        );
        assert!(r.converged, "{}", backend.name());
    }
}
