//! Cross-module integration tests: solver × models × io × comm × runtime.
//!
//! Unit tests live inside each module; these exercise full user-visible
//! flows — generate → save → distributed load → solve → validate — plus
//! the cross-layer consistency checks DESIGN.md §9 calls out.

use madupite::comm::World;
use madupite::ksp::precond::PcType;
use madupite::ksp::KspType;
use madupite::mdp::{io, DistMdp, Mdp};
use madupite::models::{
    garnet::GarnetSpec, gridworld::GridSpec, inventory::InventorySpec, queueing::QueueSpec,
    sis::SisSpec, traffic::TrafficSpec, ModelGenerator,
};
use madupite::solver::{gather_result, solve_dist, solve_serial, solve_world, Method, SolveOptions};
use std::sync::Arc;

fn tmpfile(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("madupite-integration");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert!(
            (a[i] - b[i]).abs() <= tol * (1.0 + a[i].abs().max(b[i].abs())),
            "{what}: element {i}: {} vs {}",
            a[i],
            b[i]
        );
    }
}

/// Every model family × every method agrees on V* (the C1 generality
/// claim end-to-end).
#[test]
fn all_models_all_methods_agree() {
    let models: Vec<(&str, Box<dyn ModelGenerator>, f64)> = vec![
        ("maze", Box::new(GridSpec::maze(9, 9, 3)), 0.95),
        ("sis", Box::new(SisSpec::standard(40, 3)), 0.95),
        ("traffic", Box::new(TrafficSpec::standard(4)), 0.95),
        ("garnet", Box::new(GarnetSpec::new(50, 3, 4, 7)), 0.95),
        ("inventory", Box::new(InventorySpec::standard(10)), 0.95),
        ("queueing", Box::new(QueueSpec::standard(10)), 0.95),
    ];
    let methods = [
        Method::Vi,
        Method::Mpi { sweeps: 15 },
        Method::ExactPi,
        Method::ipi_gmres(),
        Method::ipi_bicgstab(),
        Method::ipi_tfqmr(),
    ];
    for (name, gen, gamma) in &models {
        let mdp = gen.build_serial(*gamma);
        let mut reference: Option<Vec<f64>> = None;
        for method in &methods {
            let r = solve_serial(
                &mdp,
                &SolveOptions {
                    method: method.clone(),
                    atol: 1e-9,
                    max_outer: 100_000,
                    ..Default::default()
                },
            );
            assert!(r.converged, "{name}/{} did not converge", method.name());
            match &reference {
                None => reference = Some(r.value),
                Some(v) => close(v, &r.value, 1e-6, &format!("{name}/{}", method.name())),
            }
        }
    }
}

/// generate → save → load (serial) → load_dist (several world sizes) →
/// solve: all paths give the same V*.
#[test]
fn file_roundtrip_preserves_solution() {
    let spec = GarnetSpec::new(80, 3, 5, 99);
    let mdp = spec.build_serial(0.95);
    let path = tmpfile("garnet80.mdpb");
    io::save(&mdp, &path).unwrap();

    let opts = SolveOptions {
        method: Method::ipi_gmres(),
        atol: 1e-9,
        ..Default::default()
    };
    let direct = solve_serial(&mdp, &opts);
    let loaded = solve_serial(&io::load(&path).unwrap(), &opts);
    close(&direct.value, &loaded.value, 1e-9, "serial load");

    for ranks in [2usize, 3] {
        let path2 = path.clone();
        let opts2 = opts.clone();
        let mut out = World::run(ranks, move |comm| {
            let dm = io::load_dist(&comm, &path2).unwrap();
            let local = solve_dist(&comm, &dm, &opts2);
            gather_result(&comm, local)
        });
        let r = out.swap_remove(0);
        close(&direct.value, &r.value, 1e-7, &format!("dist load ranks={ranks}"));
        assert_eq!(direct.policy, r.policy);
    }
}

/// Distributed solve must be invariant in the number of ranks (C3).
#[test]
fn rank_count_invariance() {
    let spec = Arc::new(GridSpec::maze(17, 23, 5));
    let opts = SolveOptions {
        method: Method::ipi_gmres(),
        atol: 1e-9,
        max_outer: 100_000,
        ..Default::default()
    };
    let mut reference: Option<Vec<f64>> = None;
    for ranks in [1usize, 2, 4, 5] {
        let spec2 = Arc::clone(&spec);
        let opts2 = opts.clone();
        let mut out = World::run(ranks, move |comm| {
            let dm = spec2.build_dist(&comm, 0.95);
            let local = solve_dist(&comm, &dm, &opts2);
            gather_result(&comm, local)
        });
        let r = out.swap_remove(0);
        assert!(r.converged);
        match &reference {
            None => reference = Some(r.value),
            Some(v) => close(v, &r.value, 1e-7, &format!("ranks={ranks}")),
        }
    }
}

/// Filler-built DistMdp equals serial-then-distributed (C5: online path).
#[test]
fn online_and_offline_construction_agree() {
    let spec = Arc::new(SisSpec::standard(60, 4));
    let serial = Arc::new(spec.build_serial(0.9));
    let spec2 = Arc::clone(&spec);
    let serial2 = Arc::clone(&serial);
    World::run(3, move |comm| {
        let online = spec2.build_dist(&comm, 0.9);
        let offline = DistMdp::from_serial(&comm, &serial2);
        assert_eq!(online.local_states(), offline.local_states());
        assert_eq!(online.local_costs(), offline.local_costs());
        assert_eq!(
            online.transitions().nnz_local(),
            offline.transitions().nnz_local()
        );
    });
}

/// The returned policy must be greedy for the returned value function and
/// ε-optimal: exact evaluation of the policy must be within tolerance of V*.
#[test]
fn policy_quality_certificate() {
    let spec = InventorySpec::standard(20);
    let mdp = spec.build_serial(0.9);
    let r = solve_serial(
        &mdp,
        &SolveOptions {
            method: Method::ipi_bicgstab(),
            atol: 1e-10,
            ..Default::default()
        },
    );
    assert!(r.converged);
    let v_pi = mdp.evaluate_policy_exact(&r.policy);
    close(&r.value, &v_pi, 1e-6, "V vs exact V^π");
    let (_, greedy) = mdp.bellman(&r.value);
    assert_eq!(greedy, r.policy);
}

/// CLI smoke: generate a file, inspect it, solve from it.
#[test]
fn cli_generate_info_solve() {
    let exe = env!("CARGO_BIN_EXE_madupite");
    let path = tmpfile("cli_garnet.mdpb");
    let out = std::process::Command::new(exe)
        .args([
            "generate", "-model", "garnet", "-num_states", "60", "-branching", "4",
            "-gamma", "0.9", "-file", path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let out = std::process::Command::new(exe)
        .args(["info", "-file", path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("n_states=60"), "{text}");

    let json_path = tmpfile("cli_result.json");
    let out = std::process::Command::new(exe)
        .args([
            "solve", "-file", path.to_str().unwrap(), "-method", "ipi",
            "-ksp_type", "bicgstab", "-ranks", "2", "-atol", "1e-8",
            "-json", json_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("converged=true"), "{text}");
    let json = std::fs::read_to_string(&json_path).unwrap();
    assert!(json.contains("residual_trace"));
}

/// CLI solve directly from a generator spec across methods.
#[test]
fn cli_solve_model_methods() {
    let exe = env!("CARGO_BIN_EXE_madupite");
    for method in ["vi", "mpi", "ipi"] {
        let out = std::process::Command::new(exe)
            .args([
                "solve", "-model", "maze", "-rows", "12", "-cols", "12",
                "-gamma", "0.9", "-method", method, "-atol", "1e-7",
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "method={method}");
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(text.contains("converged=true"), "method={method}: {text}");
    }
}

/// Runtime cross-layer check: PJRT artifact result equals the sparse
/// solver on the same dense block (skipped when artifacts are missing).
#[test]
fn pjrt_artifact_agrees_with_sparse_solver() {
    let Ok(mut engine) = madupite::runtime::Engine::load("artifacts") else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let db = madupite::runtime::DenseBellman::new(&engine, 64, 4).unwrap();
    let (p, g, _) = madupite::runtime::random_block(5, 64, 4);
    let gamma = 0.9f32;
    let (v_pjrt, _, _) = db.solve_vi(&mut engine, &p, &g, gamma, 1e-5, 5_000).unwrap();

    // same block through the sparse path
    let mut rows = Vec::new();
    let mut costs = Vec::new();
    for s in 0..64 {
        for a in 0..4 {
            let raw: Vec<f64> = (0..64).map(|t| p[a * 64 * 64 + s * 64 + t] as f64).collect();
            let sum: f64 = raw.iter().sum();
            rows.push(
                raw.into_iter()
                    .enumerate()
                    .map(|(t, x)| (t, x / sum))
                    .collect::<Vec<_>>(),
            );
            costs.push(g[a * 64 + s] as f64);
        }
    }
    let mdp = Mdp::new(
        64,
        4,
        madupite::linalg::Csr::from_row_lists(64, rows),
        costs,
        gamma as f64,
    )
    .unwrap();
    let r = solve_serial(
        &mdp,
        &SolveOptions {
            atol: 1e-9,
            ..Default::default()
        },
    );
    for (a, b) in v_pjrt.iter().zip(&r.value) {
        assert!((*a as f64 - b).abs() < 1e-3, "{a} vs {b}");
    }
}

/// Preconditioner variants agree through the full solver.
#[test]
fn preconditioners_end_to_end() {
    let mdp = GarnetSpec::new(70, 3, 5, 31).build_serial(0.99);
    let mut reference: Option<Vec<f64>> = None;
    for pc in [PcType::None, PcType::Jacobi, PcType::Sor] {
        let r = solve_serial(
            &mdp,
            &SolveOptions {
                method: Method::Ipi {
                    ksp: KspType::Gmres { restart: 30 },
                    pc,
                },
                atol: 1e-9,
                ..Default::default()
            },
        );
        assert!(r.converged, "pc={pc:?}");
        match &reference {
            None => reference = Some(r.value),
            Some(v) => close(v, &r.value, 1e-6, &format!("pc={pc:?}")),
        }
    }
}

/// Baselines and madupite agree on a shared workload (E5 sanity).
#[test]
fn baselines_agree_with_solver() {
    let mdp = GarnetSpec::new(40, 3, 4, 17).build_serial(0.9);
    let ours = solve_serial(
        &mdp,
        &SolveOptions {
            atol: 1e-10,
            ..Default::default()
        },
    );
    let nested = madupite::baseline::mdpsolver_like::NestedVecMdp::from_mdp(&mdp)
        .solve_mpi(1e-10, 20, 100_000);
    let dense = madupite::baseline::pymdp_like::DenseMdp::from_mdp(&mdp).solve_vi(1e-9, 100_000);
    assert!(nested.converged && dense.converged);
    close(&ours.value, &nested.value, 1e-6, "vs mdpsolver-like");
    // pymdp's span rule stops when V is within a near-constant offset of V*
    // (ε-optimal policy, biased value) — so compare the *policy*, and the
    // policy's exact evaluation, not the raw iterate.
    let mismatches = ours
        .policy
        .iter()
        .zip(&dense.policy)
        .filter(|(a, b)| a != b)
        .count();
    assert!(mismatches <= 1, "pymdp-like policy differs in {mismatches} states");
    let v_dense_pi = mdp.evaluate_policy_exact(&dense.policy);
    close(&ours.value, &v_dense_pi, 1e-4, "vs pymdp-like policy value");
}

/// Large sparse workload solved distributed with every Krylov method.
#[test]
fn krylov_methods_large_distributed() {
    let spec = Arc::new(GarnetSpec::new(2_000, 4, 5, 77));
    for method in [Method::ipi_gmres(), Method::ipi_bicgstab(), Method::ipi_tfqmr()] {
        let r = solve_world(
            Arc::new(spec.build_serial(0.99)),
            3,
            &SolveOptions {
                method: method.clone(),
                atol: 1e-8,
                ..Default::default()
            },
        );
        assert!(r.converged, "{}", method.name());
        assert!(r.residual < 1e-8);
    }
}
