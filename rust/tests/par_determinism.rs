//! Hybrid-parallel determinism gate (DESIGN.md §11).
//!
//! The `-threads` dimension must change *only* wall time, never results:
//! `util::par` runs every kernel over a fixed chunk grid (a function of
//! the problem size alone) and folds per-chunk partials in chunk order, so
//! values, policies and residual traces are **bitwise identical** for any
//! thread count. This suite pins that across the method × backend matrix,
//! on serial and multi-rank worlds, and checks the `-threads` option's
//! typed-error surface.

use madupite::api::options::resolve_threads;
use madupite::api::{MdpBuilder, Solver};
use madupite::comm::{overlap, OverlapMode, World};
use madupite::factored::compile_to_mdpb;
use madupite::ksp::precond::PcType;
use madupite::ksp::KspType;
use madupite::mdp::{io, Objective};
use madupite::models::{garnet::GarnetSpec, sis_factored::SisFactoredSpec, ModelGenerator};
use madupite::solver::{
    solve_world, EvalBackend, InnerPrecision, Method, SolveOptions, SolveResult,
};
use madupite::util::args::Options;
use madupite::util::par;
use std::sync::{Arc, Mutex};

/// `par::set_threads` is process-global and `SolveResult::threads` reports
/// it, so the tests in this binary serialize on one lock (the determinism
/// guarantee itself needs no lock — that is the point — but the shape
/// assertions do).
static THREADS_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    THREADS_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// The full outer-method matrix (small MDPs, so ExactPi is fine too).
fn methods() -> Vec<Method> {
    vec![
        Method::Vi,
        Method::Mpi { sweeps: 5 },
        Method::ExactPi,
        Method::ipi_gmres(),
        Method::ipi_bicgstab(),
        Method::ipi_tfqmr(),
        Method::Ipi {
            ksp: KspType::Richardson { omega: 1.0 },
            pc: PcType::Jacobi,
        },
    ]
}

/// Everything a thread count must not change, reduced to exact bits:
/// values, policy, convergence flags/counters, and the residual trace
/// (residual bits + inner-iteration/spmv counts; wall times excluded).
type Fingerprint = (
    Vec<u64>,
    Vec<usize>,
    bool,
    usize,
    usize,
    Vec<(u64, usize, usize)>,
);

fn fingerprint(r: &SolveResult) -> Fingerprint {
    (
        r.value.iter().map(|v| v.to_bits()).collect(),
        r.policy.clone(),
        r.converged,
        r.outer_iterations,
        r.total_spmvs,
        r.trace
            .iter()
            .map(|t| (t.residual.to_bits(), t.inner_iterations, t.spmvs))
            .collect(),
    )
}

#[test]
fn solver_bitwise_identical_across_thread_counts() {
    let _guard = lock();
    // Small matrix covering every method × backend × ranks combination
    // (ExactPi's gathered dense LU caps the size).
    let mdp = Arc::new(GarnetSpec::new(400, 4, 5, 99).build_serial(0.95));
    for ranks in [1usize, 3] {
        for method in methods() {
            for backend in [
                EvalBackend::MatFree,
                EvalBackend::Assembled,
                EvalBackend::Bsr,
            ] {
                let opts = SolveOptions {
                    method: method.clone(),
                    eval_backend: backend,
                    atol: 1e-9,
                    ..Default::default()
                };
                let mut reference = None;
                for threads in [1usize, 2, 8] {
                    par::set_threads(threads);
                    let r = solve_world(Arc::clone(&mdp), ranks, &opts);
                    assert!(
                        r.converged,
                        "{}/{}/ranks={ranks}/threads={threads} did not converge",
                        method.name(),
                        backend.name()
                    );
                    assert_eq!(r.threads, threads, "SolveResult must report -threads");
                    assert_eq!(r.ranks, ranks, "SolveResult must report ranks");
                    let fp = fingerprint(&r);
                    match &reference {
                        None => reference = Some(fp),
                        Some(re) => assert_eq!(
                            re,
                            &fp,
                            "{}/{}/ranks={ranks}: threads={threads} diverged from threads=1",
                            method.name(),
                            backend.name()
                        ),
                    }
                }
            }
        }
    }
    par::set_threads(1);
}

#[test]
fn solver_bitwise_identical_above_the_parallel_threshold() {
    let _guard = lock();
    // Large enough that every threaded path really runs chunked parallel
    // regions (n > MIN_PAR states, n·m rows in the stacked SpMV, length-n
    // KSP vectors) — ExactPi/direct excluded, dense LU at this size is
    // not a unit-test workload.
    let n = 2 * par::MIN_PAR;
    let mdp = Arc::new(GarnetSpec::new(n, 3, 5, 11).build_serial(0.95));
    let methods = [
        Method::Vi,
        Method::Mpi { sweeps: 5 },
        Method::ipi_gmres(),
        Method::ipi_bicgstab(),
        Method::ipi_tfqmr(),
    ];
    for method in methods {
        for backend in [
            EvalBackend::MatFree,
            EvalBackend::Assembled,
            EvalBackend::Bsr,
        ] {
            let opts = SolveOptions {
                method: method.clone(),
                eval_backend: backend,
                atol: 1e-8,
                max_outer: 100_000,
                ..Default::default()
            };
            let mut reference = None;
            for threads in [1usize, 2, 8] {
                par::set_threads(threads);
                let r = solve_world(Arc::clone(&mdp), 1, &opts);
                assert!(
                    r.converged,
                    "{}/{}/threads={threads} did not converge",
                    method.name(),
                    backend.name()
                );
                let fp = fingerprint(&r);
                match &reference {
                    None => reference = Some(fp),
                    Some(re) => assert_eq!(
                        re,
                        &fp,
                        "{}/{}: threads={threads} diverged from threads=1",
                        method.name(),
                        backend.name()
                    ),
                }
            }
        }
    }
    par::set_threads(1);
}

/// The mixed-precision path (`-inner_precision f32`) shares the fixed
/// chunk grid: the f32 narrowing, the widened-accumulation gathers, and
/// the f64 refinement residuals are all functions of the problem alone,
/// so its results are bitwise thread-count independent too.
#[test]
fn f32_inner_bitwise_identical_across_thread_counts() {
    let _guard = lock();
    let mdp = Arc::new(GarnetSpec::new(400, 4, 5, 99).build_serial(0.95));
    for ranks in [1usize, 3] {
        for backend in [
            EvalBackend::MatFree,
            EvalBackend::Assembled,
            EvalBackend::Bsr,
        ] {
            let opts = SolveOptions {
                method: Method::ipi_gmres(),
                eval_backend: backend,
                inner_precision: InnerPrecision::F32,
                atol: 1e-9,
                ..Default::default()
            };
            let mut reference = None;
            for threads in [1usize, 4] {
                par::set_threads(threads);
                let r = solve_world(Arc::clone(&mdp), ranks, &opts);
                assert!(
                    r.converged,
                    "f32-inner/{}/ranks={ranks}/threads={threads} did not converge",
                    backend.name()
                );
                let fp = fingerprint(&r);
                match &reference {
                    None => reference = Some(fp),
                    Some(re) => assert_eq!(
                        re,
                        &fp,
                        "f32-inner/{}/ranks={ranks}: threads={threads} diverged",
                        backend.name()
                    ),
                }
            }
        }
    }
    par::set_threads(1);
}

#[test]
fn nonconverged_trace_is_thread_count_independent_and_complete() {
    let _guard = lock();
    // Exercises the post-loop residual re-check path: the trace must
    // record the final backup (one extra record beyond outer_iterations)
    // identically at every thread count.
    let mdp = Arc::new(GarnetSpec::new(300, 3, 4, 7).build_serial(0.99));
    let opts = SolveOptions {
        method: Method::Vi,
        atol: 1e-300,
        max_outer: 4,
        ..Default::default()
    };
    let mut reference = None;
    for threads in [1usize, 2, 8] {
        par::set_threads(threads);
        let r = solve_world(Arc::clone(&mdp), 1, &opts);
        assert!(!r.converged);
        assert_eq!(r.outer_iterations, 4);
        assert_eq!(r.trace.len(), 5, "final residual re-check must be traced");
        assert_eq!(r.trace.last().unwrap().spmvs, 1);
        let spmvs_traced: usize = r.trace.iter().map(|t| t.spmvs).sum();
        assert_eq!(spmvs_traced, r.total_spmvs, "trace must account every backup");
        let fp = fingerprint(&r);
        match &reference {
            None => reference = Some(fp),
            Some(re) => assert_eq!(re, &fp, "threads={threads} diverged"),
        }
    }
    par::set_threads(1);
}

/// The `-comm_overlap` dimension must change *only* the communication
/// schedule, never results (DESIGN.md §14): the split-phase exchange moves
/// the identical ghost f64s and both schedules evaluate every row with the
/// identical kernel over the identical chunk grid. Pinned bitwise across
/// the method × backend × ranks × threads matrix. (`overlap::set_mode` is
/// process-global like `par::set_threads`, hence the shared lock; Auto is
/// restored on exit so the other tests keep the default behavior.)
#[test]
fn comm_overlap_on_off_bitwise_identical() {
    let _guard = lock();
    let mdp = Arc::new(GarnetSpec::new(400, 4, 5, 99).build_serial(0.95));
    for ranks in [1usize, 3] {
        for method in methods() {
            for backend in [
                EvalBackend::MatFree,
                EvalBackend::Assembled,
                EvalBackend::Bsr,
            ] {
                let opts = SolveOptions {
                    method: method.clone(),
                    eval_backend: backend,
                    atol: 1e-9,
                    ..Default::default()
                };
                for threads in [1usize, 4] {
                    par::set_threads(threads);
                    overlap::set_mode(OverlapMode::Off);
                    let off = solve_world(Arc::clone(&mdp), ranks, &opts);
                    overlap::set_mode(OverlapMode::On);
                    let on = solve_world(Arc::clone(&mdp), ranks, &opts);
                    assert!(
                        off.converged && on.converged,
                        "{}/{}/ranks={ranks}/threads={threads} did not converge",
                        method.name(),
                        backend.name()
                    );
                    assert_eq!(
                        fingerprint(&off),
                        fingerprint(&on),
                        "{}/{}/ranks={ranks}/threads={threads}: overlap on diverged from off",
                        method.name(),
                        backend.name()
                    );
                }
            }
        }
    }
    overlap::set_mode(OverlapMode::Auto);
    par::set_threads(1);
}

/// Warm starting is result-neutral (DESIGN.md §16): seeding a solve with
/// the converged value of the same model (`SolveOptions::v0`, the carrier
/// behind `-warm_start`) must return the *identical* value vector bitwise —
/// the convergence check fires before any update — plus the identical
/// greedy policy, in exactly one outer iteration, across the full
/// method × backend × ranks × threads matrix. The seed is the global
/// vector and every rank slices its own block, so the equality also pins
/// rank-partition independence of the scatter.
#[test]
fn warm_start_bitwise_equals_cold_across_matrix() {
    let _guard = lock();
    let mdp = Arc::new(GarnetSpec::new(400, 4, 5, 99).build_serial(0.95));
    for ranks in [1usize, 4] {
        for method in methods() {
            for backend in [
                EvalBackend::MatFree,
                EvalBackend::Assembled,
                EvalBackend::Bsr,
            ] {
                for threads in [1usize, 4] {
                    par::set_threads(threads);
                    let opts = SolveOptions {
                        method: method.clone(),
                        eval_backend: backend,
                        atol: 1e-9,
                        ..Default::default()
                    };
                    let cold = solve_world(Arc::clone(&mdp), ranks, &opts);
                    assert!(
                        cold.converged,
                        "{}/{}/ranks={ranks}/threads={threads} did not converge",
                        method.name(),
                        backend.name()
                    );
                    let warm_opts = SolveOptions {
                        v0: Some(cold.value.clone()),
                        ..opts
                    };
                    let warm = solve_world(Arc::clone(&mdp), ranks, &warm_opts);
                    assert!(warm.converged);
                    assert_eq!(
                        warm.outer_iterations,
                        1,
                        "{}/{}/ranks={ranks}/threads={threads}: a converged seed must \
                         terminate at the first residual check",
                        method.name(),
                        backend.name()
                    );
                    let cold_bits: Vec<u64> = cold.value.iter().map(|v| v.to_bits()).collect();
                    let warm_bits: Vec<u64> = warm.value.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(
                        warm_bits,
                        cold_bits,
                        "{}/{}/ranks={ranks}/threads={threads}: warm value diverged from cold",
                        method.name(),
                        backend.name()
                    );
                    assert_eq!(
                        warm.policy,
                        cold.policy,
                        "{}/{}/ranks={ranks}/threads={threads}: warm policy diverged from cold",
                        method.name(),
                        backend.name()
                    );
                }
            }
        }
    }
    par::set_threads(1);
}

/// Bounded-staleness async VI is deterministic too: the sweep schedule is
/// collectively agreed, the stale sweeps run on the fixed chunk grid, and
/// the overlap schedule of the synchronized backups is bitwise-neutral —
/// so for a fixed (ranks, staleness) the entire solve is bitwise identical
/// across thread counts and overlap modes.
#[test]
fn async_vi_bitwise_across_threads_and_overlap() {
    let _guard = lock();
    let mdp = Arc::new(GarnetSpec::new(400, 4, 5, 99).build_serial(0.95));
    let opts = SolveOptions {
        method: Method::Vi,
        async_vi: true,
        async_vi_staleness: 4,
        atol: 1e-9,
        max_outer: 100_000,
        ..Default::default()
    };
    for ranks in [1usize, 3] {
        let mut reference = None;
        for threads in [1usize, 4] {
            for mode in [OverlapMode::Off, OverlapMode::On] {
                par::set_threads(threads);
                overlap::set_mode(mode);
                let r = solve_world(Arc::clone(&mdp), ranks, &opts);
                assert!(
                    r.converged,
                    "async-vi/ranks={ranks}/threads={threads}/overlap={} did not converge",
                    mode.name()
                );
                let fp = fingerprint(&r);
                match &reference {
                    None => reference = Some(fp),
                    Some(re) => assert_eq!(
                        re,
                        &fp,
                        "async-vi/ranks={ranks}: threads={threads}/overlap={} diverged",
                        mode.name()
                    ),
                }
            }
        }
    }
    overlap::set_mode(OverlapMode::Auto);
    par::set_threads(1);
}

/// The factored compile path (DESIGN.md §17) joins the determinism gate:
/// the `.mdpb` bytes a factored spec streams out are identical for every
/// (ranks, threads) combination, and the flat solve of the compiled file
/// is bitwise thread-count independent at each world size.
#[test]
fn factored_compile_bitwise_across_ranks_and_threads() {
    let _guard = lock();
    let fmdp = Arc::new(
        SisFactoredSpec::new(6)
            .unwrap()
            .factored_mdp()
            .clone(),
    );
    let dir = std::env::temp_dir().join("madupite-par-factored");
    std::fs::create_dir_all(&dir).unwrap();
    let opts = SolveOptions {
        method: Method::Vi,
        atol: 1e-10,
        max_outer: 100_000,
        ..Default::default()
    };
    let mut reference_bytes: Option<Vec<u8>> = None;
    for ranks in [1usize, 3] {
        let mut reference_fp = None;
        for threads in [1usize, 4] {
            par::set_threads(threads);
            let path = dir.join(format!(
                "sis6_r{ranks}_t{threads}_{}.mdpb",
                std::process::id()
            ));
            {
                let fmdp = Arc::clone(&fmdp);
                let path = path.clone();
                World::run(ranks, move |comm| {
                    compile_to_mdpb(&fmdp, &comm, &path, 0.95, Objective::Min, 16).unwrap();
                });
            }
            let bytes = std::fs::read(&path).unwrap();
            match &reference_bytes {
                None => reference_bytes = Some(bytes),
                Some(rb) => assert_eq!(
                    rb, &bytes,
                    "compiled bytes differ at ranks={ranks}/threads={threads}"
                ),
            }
            let mdp = Arc::new(io::load(&path).unwrap());
            let r = solve_world(mdp, ranks, &opts);
            assert!(
                r.converged,
                "factored-compile/ranks={ranks}/threads={threads} did not converge"
            );
            let fp = fingerprint(&r);
            match &reference_fp {
                None => reference_fp = Some(fp),
                Some(re) => assert_eq!(
                    re,
                    &fp,
                    "factored-compile/ranks={ranks}: threads={threads} diverged"
                ),
            }
        }
    }
    par::set_threads(1);
}

fn db(tokens: &[&str]) -> Options {
    Options::parse(tokens.iter().map(|s| s.to_string()))
}

#[test]
fn threads_option_zero_and_negative_are_typed_errors() {
    let err = resolve_threads(&db(&["-threads", "0"])).unwrap_err();
    assert!(err.0.contains("threads"), "{err}");
    assert!(err.0.contains(">= 1"), "{err}");
    let err = resolve_threads(&db(&["-threads", "-4"])).unwrap_err();
    assert!(err.0.contains("expected integer"), "{err}");
    assert_eq!(resolve_threads(&db(&["-threads", "3"])).unwrap(), 3);
}

fn two_state_builder() -> MdpBuilder {
    MdpBuilder::from_fillers(
        2,
        2,
        |s, a| match (s, a) {
            (0, 0) => vec![(0, 1.0)],
            (0, 1) => vec![(1, 1.0)],
            _ => vec![(1, 1.0)],
        },
        |s, a| match (s, a) {
            (0, 0) => 1.0,
            (0, 1) => 1.5,
            _ => 0.0,
        },
    )
    .gamma(0.5)
}

#[test]
fn threads_option_end_to_end_through_the_api() {
    let _guard = lock();
    // -threads 0 errors before any world spawns…
    let mut solver = Solver::new(two_state_builder());
    solver.set_option("-threads", "0").unwrap();
    let err = solver.solve().unwrap_err();
    assert!(err.0.contains(">= 1"), "{err}");

    // …a typo'd key keeps the did-you-mean surface…
    let mut solver = Solver::new(two_state_builder());
    let err = solver.set_option("-thraeds", "2").unwrap_err();
    assert!(err.0.contains("threads"), "{err}");

    // …and a threaded solve reports its shape and matches serial bitwise.
    let mut serial = Solver::new(two_state_builder());
    serial.set_option("-threads", "1").unwrap();
    let serial = serial.solve().unwrap();
    let mut threaded = Solver::new(two_state_builder());
    threaded.set_option("-threads", "2").unwrap();
    let threaded = threaded.solve().unwrap();
    assert_eq!(threaded.threads, 2);
    assert_eq!(
        threaded.metadata_json().get("solver").unwrap().get("threads").unwrap().as_f64(),
        Some(2.0)
    );
    assert_eq!(fingerprint(&serial.result), fingerprint(&threaded.result));
    par::set_threads(1);
}
