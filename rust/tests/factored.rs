//! Cross-representation conformance suite for factored MDPs
//! (DESIGN.md §17).
//!
//! The headline guarantee of the ADD backend: on every factored model with
//! an enumerable flat space, SPUDD-style structured value iteration and
//! compile-then-flat-solve agree to 1e-9 in value and *exactly* in policy,
//! across ranks × threads on the flat side. The two paths share nothing
//! past the spec — the structured solver computes on decision diagrams,
//! the compile path streams the flattened kernel through the `.mdpb`
//! writer and solves with the distributed flat machinery — so agreement
//! pins the whole stack: CPT normalization, the mixed-radix flat encoding,
//! the ADD apply/marginalize algebra, the greedy tie-break, and the
//! streaming writer.
//!
//! Also here: ADD canonicity properties (`util::prop`), elimination-order
//! invariance, and the typed-error surface of the spec and the options
//! layer.

use madupite::api::{run_solve, MdpBuilder};
use madupite::comm::World;
use madupite::factored::{
    compile_to_mdpb, solve_svi, AddStore, CostTerm, Cpt, FactoredError, FactoredMdp,
    FactoredOrder, Op, SviOptions, VarSpec, MAX_ENUMERABLE_STATES,
};
use madupite::mdp::{io, Objective};
use madupite::models::{factory::FactorySpec, sis_factored::SisFactoredSpec};
use madupite::prop_assert;
use madupite::solver::{solve_world, Method, SolveOptions};
use madupite::util::args::Options;
use madupite::util::par;
use madupite::util::prop;
use std::sync::{Arc, Mutex};

/// `par::set_threads` is process-global, so the tests that sweep thread
/// counts serialize on one lock (same idiom as `tests/par_determinism.rs`).
static THREADS_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    THREADS_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn db(toks: &[&str]) -> Options {
    Options::parse(toks.iter().map(|s| s.to_string()))
}

fn tmpfile(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("madupite-factored");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{}_{name}", std::process::id()))
}

/// The conformance check itself: structured VI vs compile-then-flat-solve
/// on one factored model, 1e-9 values and identical policies, flat side
/// swept over ranks {1, 3} × threads {1, 4}. Caller holds the thread lock.
fn assert_conformance(tag: &str, fmdp: &FactoredMdp, gamma: f64, objective: Objective) {
    let svi = solve_svi(
        fmdp,
        gamma,
        objective,
        &SviOptions {
            atol: 1e-12,
            max_iter: 100_000,
            order: FactoredOrder::Given,
        },
    )
    .unwrap();
    assert!(svi.converged, "{tag}: structured VI did not converge");
    assert_eq!(svi.value.len(), fmdp.n_states());

    let path = tmpfile(&format!("{tag}.mdpb"));
    {
        let f = Arc::new(fmdp.clone());
        let path = path.clone();
        World::run(1, move |comm| {
            compile_to_mdpb(&f, &comm, &path, gamma, objective, 32).unwrap();
        });
    }
    let mdp = Arc::new(io::load(&path).unwrap());
    assert_eq!(mdp.n_states(), fmdp.n_states(), "{tag}: compiled state count");
    assert_eq!(mdp.n_actions(), fmdp.n_actions(), "{tag}: compiled action count");

    let opts = SolveOptions {
        method: Method::Vi,
        atol: 1e-12,
        max_outer: 100_000,
        ..Default::default()
    };
    for ranks in [1usize, 3] {
        for threads in [1usize, 4] {
            par::set_threads(threads);
            let flat = solve_world(Arc::clone(&mdp), ranks, &opts);
            assert!(
                flat.converged,
                "{tag}/ranks={ranks}/threads={threads}: flat solve did not converge"
            );
            let err = prop::max_abs_diff(&svi.value, &flat.value);
            assert!(
                err < 1e-9,
                "{tag}/ranks={ranks}/threads={threads}: values differ by {err:e}"
            );
            assert_eq!(
                svi.policy, flat.policy,
                "{tag}/ranks={ranks}/threads={threads}: policies differ"
            );
        }
    }
    par::set_threads(1);
}

/// A handcrafted spec exercising the corners the catalog models do not:
/// mixed domain sizes, a scope listed out of variable order, an
/// empty-scope CPT, an empty-scope (pure per-action) cost term, and a
/// cost term over a non-contiguous scope.
fn mixed_domains() -> FactoredMdp {
    let mut cpt1_rows = Vec::new();
    for a in 0..2usize {
        for u in 0..6usize {
            let w = [
                1.0 + ((a + u) % 3) as f64 * 0.71,
                2.0 + (u % 2) as f64 * 0.37,
                1.0 + a as f64 * 0.53,
            ];
            let s: f64 = w.iter().sum();
            cpt1_rows.extend(w.iter().map(|x| x / s));
        }
    }
    FactoredMdp::new(
        vec![
            VarSpec::new("x0", 2),
            VarSpec::new("x1", 3),
            VarSpec::new("x2", 2),
        ],
        2,
        vec![
            Cpt {
                var: 0,
                scope: vec![2],
                rows: vec![0.7, 0.3, 0.4, 0.6, 0.9, 0.1, 0.2, 0.8],
            },
            Cpt {
                var: 1,
                scope: vec![1, 0], // deliberately not in variable order
                rows: cpt1_rows,
            },
            Cpt {
                var: 2,
                scope: vec![],
                rows: vec![0.55, 0.45, 0.35, 0.65],
            },
        ],
        vec![
            CostTerm {
                scope: vec![0, 2], // skips x1
                values: vec![0.0, 1.13, 0.41, 1.79, 0.29, 1.23, 0.67, 1.97],
            },
            CostTerm {
                scope: vec![1],
                values: vec![0.0, 0.21, 0.77, 0.11, 0.33, 0.93],
            },
            CostTerm {
                scope: vec![],
                values: vec![0.05, 0.52],
            },
        ],
    )
    .unwrap()
}

// ---------------------------------------------------------- conformance

#[test]
fn structured_vi_matches_compile_then_flat_solve_on_catalog_models() {
    let _guard = lock();
    let sis = SisFactoredSpec::new(8).unwrap(); // 2^8 = 256 flat states
    assert_conformance("sis8", sis.factored_mdp(), 0.95, Objective::Min);
    let factory = FactorySpec::new(4).unwrap(); // 3^4 = 81 flat states
    assert_conformance("factory4", factory.factored_mdp(), 0.95, Objective::Min);
}

#[test]
fn conformance_holds_for_the_max_objective_too() {
    let _guard = lock();
    let factory = FactorySpec::new(3).unwrap();
    assert_conformance("factory3_max", factory.factored_mdp(), 0.9, Objective::Max);
}

#[test]
fn conformance_on_mixed_domains_and_irregular_scopes() {
    let _guard = lock();
    let f = mixed_domains();
    assert_eq!(f.n_states(), 12);
    assert_conformance("mixed_min", &f, 0.95, Objective::Min);
    assert_conformance("mixed_max", &f, 0.95, Objective::Max);
}

/// The API front door reaches the same two paths: `-factored_mode svi`
/// and `-factored_mode compile` through `run_solve` agree on values and
/// policies, and both report the factored shape.
#[test]
fn api_svi_and_compile_paths_agree_end_to_end() {
    let _guard = lock();
    let f = FactorySpec::new(3).unwrap().factored_mdp().clone();
    let svi = run_solve(
        &MdpBuilder::from_factored(f.clone()).gamma(0.93),
        &db(&["-factored_mode", "svi", "-atol", "1e-12", "-max_iter_pi", "100000"]),
    )
    .unwrap();
    let flat = run_solve(
        &MdpBuilder::from_factored(f.clone()).gamma(0.93),
        &db(&["-factored_mode", "compile", "-atol", "1e-12"]),
    )
    .unwrap();
    assert!(svi.result.converged && flat.result.converged);
    assert_eq!(svi.n_states, f.n_states());
    assert_eq!(svi.n_actions, f.n_actions());
    let err = prop::max_abs_diff(&svi.result.value, &flat.result.value);
    assert!(err < 1e-9, "API paths differ by {err:e}");
    assert_eq!(svi.result.policy, flat.result.policy);
    par::set_threads(1);
}

// --------------------------------------------------- ordering invariance

#[test]
fn elimination_order_never_changes_results() {
    for fmdp in [
        SisFactoredSpec::new(5).unwrap().factored_mdp().clone(),
        FactorySpec::new(3).unwrap().factored_mdp().clone(),
        mixed_domains(),
    ] {
        let base = solve_svi(
            &fmdp,
            0.95,
            Objective::Min,
            &SviOptions {
                atol: 1e-11,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(base.converged);
        for order in [FactoredOrder::Reverse, FactoredOrder::Auto] {
            let r = solve_svi(
                &fmdp,
                0.95,
                Objective::Min,
                &SviOptions {
                    atol: 1e-11,
                    order,
                    ..Default::default()
                },
            )
            .unwrap();
            assert!(r.converged, "{order:?} did not converge");
            // the ordering actually used is a permutation of the variables
            let mut seen = r.ordering.clone();
            seen.sort_unstable();
            assert_eq!(seen, (0..fmdp.n_vars()).collect::<Vec<_>>());
            let err = prop::max_abs_diff(&base.value, &r.value);
            assert!(err < 1e-9, "{order:?}: values differ by {err:e}");
            assert_eq!(base.policy, r.policy, "{order:?}: policies differ");
        }
    }
}

// --------------------------------------------------- ADD canonicity props

/// Canonicity is NodeId equality: the same function built along two
/// different construction routes (pointwise `apply` of two smaller ADDs
/// vs. direct enumeration of the combined function) must intern to the
/// *same physical node*.
#[test]
fn prop_add_canonicity_across_construction_routes() {
    prop::forall("add canonicity: apply == direct enumeration", |rng| {
        let mut s = AddStore::new(vec![2, 3, 2]);
        let palette = [0.0, 0.5, 1.0, 2.25];
        let mut fv = [0.0f64; 6]; // f over levels {0, 1}
        for v in fv.iter_mut() {
            *v = palette[rng.index(palette.len())];
        }
        let mut gv = [0.0f64; 6]; // g over levels {1, 2}
        for v in gv.iter_mut() {
            *v = palette[rng.index(palette.len())];
        }
        let f = s.build_over(&[0, 1], &mut |a| fv[a[0] * 3 + a[1]]);
        let g = s.build_over(&[1, 2], &mut |a| gv[a[0] * 2 + a[1]]);
        for op in [Op::Add, Op::Mul, Op::Min, Op::Max] {
            let via_apply = s.apply(f, g, op);
            let direct = s.build_over(&[0, 1, 2], &mut |a| {
                op_eval(op, fv[a[0] * 3 + a[1]], gv[a[1] * 2 + a[2]])
            });
            prop_assert!(
                via_apply == direct,
                "{op:?}: two construction routes interned different nodes"
            );
            for x0 in 0..2 {
                for x1 in 0..3 {
                    for x2 in 0..2 {
                        let want = op_eval(op, fv[x0 * 3 + x1], gv[x1 * 2 + x2]);
                        let got = s.eval(via_apply, &[x0, x1, x2]);
                        prop_assert!(
                            got == want,
                            "{op:?}: eval mismatch at ({x0},{x1},{x2}): {got} vs {want}"
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

fn op_eval(op: Op, a: f64, b: f64) -> f64 {
    match op {
        Op::Add => a + b,
        Op::Mul => a * b,
        Op::Min => a.min(b),
        Op::Max => a.max(b),
        _ => unreachable!("not used by the props"),
    }
}

#[test]
fn prop_restrict_and_marginalize_match_brute_force() {
    prop::forall("add restrict/marginalize vs brute force", |rng| {
        let mut s = AddStore::new(vec![2, 3, 2]);
        let mut hv = [0.0f64; 12];
        for v in hv.iter_mut() {
            *v = rng.index(8) as f64 * 0.375;
        }
        let h = s.build_over(&[0, 1, 2], &mut |a| hv[(a[0] * 3 + a[1]) * 2 + a[2]]);
        for val in 0..3 {
            let r = s.restrict(h, 1, val);
            for x0 in 0..2 {
                for x1 in 0..3 {
                    for x2 in 0..2 {
                        // the restricted diagram must ignore level 1
                        prop_assert!(
                            s.eval(r, &[x0, x1, x2]) == hv[(x0 * 3 + val) * 2 + x2],
                            "restrict(1:={val}) wrong at ({x0},{x1},{x2})"
                        );
                    }
                }
            }
        }
        let m = s.marginalize(h, 1);
        for x0 in 0..2 {
            for x2 in 0..2 {
                let want: f64 = (0..3).map(|x1| hv[(x0 * 3 + x1) * 2 + x2]).sum();
                let got = s.eval(m, &[x0, 0, x2]);
                prop_assert!(
                    (got - want).abs() < 1e-12,
                    "marginalize wrong at ({x0},·,{x2}): {got} vs {want}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_constant_functions_reduce_to_one_terminal() {
    prop::forall("add reduction: constants collapse", |rng| {
        let mut s = AddStore::new(vec![2, 3, 2]);
        let c = rng.index(5) as f64 * 0.75 - 1.5;
        let f = s.build_over(&[0, 1, 2], &mut |_| c);
        prop_assert!(
            s.terminal_value(f) == Some(c),
            "constant {c} did not reduce to its terminal"
        );
        Ok(())
    });
}

// ------------------------------------------------------------ typed errors

#[test]
fn spec_validation_errors_are_typed_and_comparable() {
    let v2 = vec![VarSpec::new("x", 2)];
    let ok = Cpt {
        var: 0,
        scope: vec![],
        rows: vec![0.5, 0.5],
    };
    assert_eq!(
        FactoredMdp::new(vec![], 1, vec![], vec![]).unwrap_err(),
        FactoredError::NoVariables
    );
    assert_eq!(
        FactoredMdp::new(v2.clone(), 0, vec![ok.clone()], vec![]).unwrap_err(),
        FactoredError::NoActions
    );
    assert_eq!(
        FactoredMdp::new(
            vec![VarSpec::new("x", 2), VarSpec::new("y", 0)],
            1,
            vec![ok.clone(), ok.clone()],
            vec![],
        )
        .unwrap_err(),
        FactoredError::EmptyDomain { var: 1 }
    );
    assert_eq!(
        FactoredMdp::new(v2.clone(), 1, vec![], vec![]).unwrap_err(),
        FactoredError::CptCount {
            expected: 1,
            got: 0
        }
    );
    // a mis-shaped table reports exactly what it required
    let short = Cpt {
        var: 0,
        scope: vec![0],
        rows: vec![0.5, 0.5], // needs 1 action * 2 parents * 2 values = 4
    };
    assert_eq!(
        FactoredMdp::new(v2.clone(), 1, vec![short], vec![]).unwrap_err(),
        FactoredError::TableLen {
            what: "cpt",
            index: 0,
            expected: 4,
            got: 2
        }
    );
    let dup = CostTerm {
        scope: vec![0, 0],
        values: vec![0.0; 4],
    };
    assert_eq!(
        FactoredMdp::new(v2.clone(), 1, vec![ok.clone()], vec![dup]).unwrap_err(),
        FactoredError::DuplicateScopeVar {
            what: "cost term",
            index: 0,
            var: 0
        }
    );
    let sub = Cpt {
        var: 0,
        scope: vec![],
        rows: vec![0.6, 0.3],
    };
    assert!(matches!(
        FactoredMdp::new(v2.clone(), 1, vec![sub], vec![]).unwrap_err(),
        FactoredError::BadDistributionSum {
            var: 0,
            action: 0,
            parent: 0,
            ..
        }
    ));
    // every error Displays without panicking (the API layer stringifies)
    let e = FactoredMdp::new(v2, 3, vec![], vec![]).unwrap_err();
    assert!(e.to_string().contains("CPT"), "{e}");
}

#[test]
fn solver_gamma_and_enumeration_limits_are_typed() {
    let f = SisFactoredSpec::new(3).unwrap().factored_mdp().clone();
    assert_eq!(
        solve_svi(&f, 1.0, Objective::Min, &SviOptions::default()).unwrap_err(),
        FactoredError::BadGamma { gamma: 1.0 }
    );
    // 23 binary variables: 2^23 flat states, above the enumeration cap —
    // the spec itself builds fine (the compile path streams), only result
    // flattening refuses.
    let n = 23usize;
    let big = FactoredMdp::new(
        (0..n).map(|i| VarSpec::new(&format!("b{i}"), 2)).collect(),
        1,
        (0..n)
            .map(|i| Cpt {
                var: i,
                scope: vec![i],
                rows: vec![0.8, 0.2, 0.3, 0.7],
            })
            .collect(),
        vec![],
    )
    .unwrap();
    assert!(big.n_states() > MAX_ENUMERABLE_STATES);
    assert_eq!(
        solve_svi(&big, 0.9, Objective::Min, &SviOptions::default()).unwrap_err(),
        FactoredError::TooLargeToEnumerate {
            n_states: 1 << 23,
            limit: MAX_ENUMERABLE_STATES
        }
    );
}

#[test]
fn options_layer_rejects_factored_knobs_off_the_factored_path() {
    let fillers = MdpBuilder::from_fillers(
        2,
        1,
        |_, _| vec![(0, 0.5), (1, 0.5)],
        |s, _| s as f64,
    )
    .gamma(0.9);
    let err = run_solve(&fillers, &db(&["-factored_mode", "svi"])).unwrap_err();
    assert!(err.0.contains("factored source"), "{err}");

    let f = SisFactoredSpec::new(3).unwrap().factored_mdp().clone();
    let err = run_solve(
        &MdpBuilder::from_factored(f.clone()).gamma(0.9),
        &db(&["-factored_mode", "svi", "-ranks", "3"]),
    )
    .unwrap_err();
    assert!(err.0.contains("serially"), "{err}");

    let err = run_solve(
        &MdpBuilder::from_factored(f).gamma(0.9),
        &db(&["-factored_order", "reverse"]),
    )
    .unwrap_err();
    assert!(err.0.contains("factored_mode svi"), "{err}");
}
