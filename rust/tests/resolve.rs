//! Warm-started incremental re-solve suite (DESIGN.md §16).
//!
//! The drift loop `solve → checkpoint → patch → warm re-solve → serve`
//! end to end:
//!
//! - **Checkpoint round-trip**: `-write_checkpoint` then `-warm_start
//!   <path>` re-solves the unchanged model in exactly one outer iteration
//!   with the bitwise-identical value/policy, and the serving fingerprint
//!   is warm-start-neutral (the provenance lives only in the metadata
//!   JSON, and only on warm solves — cold metadata bytes are untouched).
//! - **Partition independence**: a checkpoint written on 1 rank seeds a
//!   3-rank solve bitwise (the seed is the global vector; each rank
//!   slices its own block).
//! - **Corruption faults**: truncation, flipped payload bytes and missing
//!   files surface as typed `ApiError`s through `-warm_start`, mirroring
//!   the serve-store fault tests.
//! - **Compatibility**: shape/gamma/objective mismatches are typed errors
//!   naming both sides, identical on every rank (no deadlock).
//! - **Delta updates**: builder patches re-solve to the bitwise-identical
//!   result of rebuilding the drifted model from scratch; invalid patches
//!   are typed; a `PreparedModel` never re-invokes the fillers after
//!   `Solver::build`.
//! - **CLI round-trip**: the `madupite` binary closes the same loop with
//!   byte-identical `-write_cost`/`-write_policy` outputs.

use madupite::api::{run_solve, ApiError, MdpBuilder, SolveOutcome, Solver};
use madupite::serve::codec;
use madupite::util::args::Options;
use madupite::util::json::Json;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join("madupite-resolve-tests")
        .join(format!("{name}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn try_solve(args: &[&str]) -> Result<SolveOutcome, ApiError> {
    let db = Options::parse(args.iter().map(|s| s.to_string()));
    let builder = MdpBuilder::from_options(&db).unwrap();
    run_solve(&builder, &db)
}

fn solve_with(args: &[&str]) -> SolveOutcome {
    try_solve(args).unwrap()
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn checkpoint_roundtrip_one_iteration_bitwise_and_fingerprint_neutral() {
    let dir = tmp("roundtrip");
    let ck = dir.join("maze.mdpa");
    let cold = solve_with(&[
        "-model",
        "maze",
        "-rows",
        "8",
        "-cols",
        "8",
        "-write_checkpoint",
        ck.to_str().unwrap(),
    ]);
    assert_eq!(cold.warm_start, None);
    assert!(
        cold.metadata_json()
            .get("solver")
            .unwrap()
            .get("warm_start")
            .is_none(),
        "cold metadata must not grow a warm_start key"
    );
    // the checkpoint is the self-verifying .mdpa artifact of this outcome
    let artifact = codec::decode(&std::fs::read(&ck).unwrap()).unwrap();
    assert_eq!(artifact.fingerprint_hex(), cold.fingerprint());
    assert_eq!(bits(&artifact.value), bits(cold.value()));

    let warm = solve_with(&[
        "-model",
        "maze",
        "-rows",
        "8",
        "-cols",
        "8",
        "-warm_start",
        ck.to_str().unwrap(),
    ]);
    assert!(warm.result.converged);
    assert_eq!(
        warm.result.outer_iterations, 1,
        "a converged seed must terminate at the first residual check"
    );
    assert!(warm.result.outer_iterations < cold.result.outer_iterations);
    assert_eq!(bits(warm.value()), bits(cold.value()));
    assert_eq!(warm.policy(), cold.policy());
    // provenance is recorded …
    assert_eq!(warm.warm_start.as_deref(), Some(cold.fingerprint().as_str()));
    assert_eq!(
        warm.metadata_json()
            .get("solver")
            .unwrap()
            .get("warm_start")
            .and_then(Json::as_str),
        Some(cold.fingerprint().as_str())
    );
    // … but the serving fingerprint is warm-start-neutral
    assert_eq!(warm.fingerprint(), cold.fingerprint());
}

#[test]
fn warm_start_is_rank_partition_independent() {
    let dir = tmp("partition");
    let ck = dir.join("ck.mdpa");
    let cold = solve_with(&[
        "-model",
        "maintenance",
        "-num_states",
        "40",
        "-write_checkpoint",
        ck.to_str().unwrap(),
    ]);
    // seed written by a 1-rank solve, consumed by 1- and 3-rank solves:
    // the value vector is global and sliced per rank, so the partition
    // never changes the result
    for ranks in ["1", "3"] {
        let warm = solve_with(&[
            "-model",
            "maintenance",
            "-num_states",
            "40",
            "-ranks",
            ranks,
            "-warm_start",
            ck.to_str().unwrap(),
        ]);
        assert!(warm.result.converged, "ranks={ranks}");
        assert_eq!(warm.result.outer_iterations, 1, "ranks={ranks}");
        assert_eq!(bits(warm.value()), bits(cold.value()), "ranks={ranks}");
        assert_eq!(warm.policy(), cold.policy(), "ranks={ranks}");
    }
}

#[test]
fn checkpoint_corruption_faults_are_typed() {
    let dir = tmp("corrupt");
    let ck = dir.join("ck.mdpa");
    let model = &["-model", "maze", "-rows", "6", "-cols", "6"];
    let mut args = model.to_vec();
    args.extend_from_slice(&["-write_checkpoint", ck.to_str().unwrap()]);
    solve_with(&args);
    let clean = std::fs::read(&ck).unwrap();

    let warm_with = |path: &std::path::Path| {
        let mut args: Vec<String> = model.iter().map(|s| s.to_string()).collect();
        args.push("-warm_start".into());
        args.push(path.to_str().unwrap().into());
        let db = Options::parse(args);
        let builder = MdpBuilder::from_options(&db).unwrap();
        run_solve(&builder, &db)
    };

    // truncated checkpoint
    std::fs::write(&ck, &clean[..clean.len() / 2]).unwrap();
    let err = warm_with(&ck).unwrap_err();
    assert!(
        err.0.contains("truncated") || err.0.contains("length mismatch"),
        "{err}"
    );
    assert!(err.0.contains("-warm_start"), "{err}");

    // flipped payload byte — caught by the embedded digest, never a
    // silently wrong seed
    let mut bad = clean.clone();
    bad[codec::HEADER_LEN + 1] ^= 0x10;
    std::fs::write(&ck, &bad).unwrap();
    let err = warm_with(&ck).unwrap_err();
    assert!(err.0.contains("digest"), "{err}");

    // missing file
    let err = warm_with(&dir.join("nope.mdpa")).unwrap_err();
    assert!(err.0.contains("reading -warm_start"), "{err}");

    // the intact checkpoint still seeds after all faults
    std::fs::write(&ck, &clean).unwrap();
    let warm = warm_with(&ck).unwrap();
    assert_eq!(warm.result.outer_iterations, 1);
}

#[test]
fn warm_start_compat_mismatches_are_typed_on_every_rank() {
    let dir = tmp("compat");
    let ck = dir.join("ck.mdpa");
    solve_with(&[
        "-model",
        "maze",
        "-rows",
        "6",
        "-cols",
        "6",
        "-write_checkpoint",
        ck.to_str().unwrap(),
    ]);
    let ck = ck.to_str().unwrap();

    // wrong shape — and the verdict is collective: the same typed error on
    // 1 and 3 ranks, never a deadlock
    for ranks in ["1", "3"] {
        let err = try_solve(&[
            "-model", "maze", "-rows", "5", "-cols", "5", "-ranks", ranks, "-warm_start", ck,
        ])
        .unwrap_err();
        assert!(err.0.contains("states"), "ranks={ranks}: {err}");
        assert!(err.0.contains("incompatible"), "ranks={ranks}: {err}");
    }

    // wrong gamma (checked bitwise)
    let err = try_solve(&[
        "-model", "maze", "-rows", "6", "-cols", "6", "-gamma", "0.5", "-warm_start", ck,
    ])
    .unwrap_err();
    assert!(err.0.contains("gamma"), "{err}");

    // wrong objective
    let err = try_solve(&[
        "-model",
        "maze",
        "-rows",
        "6",
        "-cols",
        "6",
        "-objective",
        "max",
        "-warm_start",
        ck,
    ])
    .unwrap_err();
    assert!(err.0.contains("objective"), "{err}");
}

#[test]
fn fingerprint_warm_start_resolves_through_the_store() {
    let dir = tmp("store");
    let store = dir.join("artifacts");
    let store = store.to_str().unwrap();
    let cold = solve_with(&[
        "-model",
        "replacement",
        "-num_states",
        "30",
        "-serve_store",
        store,
    ]);
    let fp = cold.fingerprint();

    // fingerprint + store: resolved via the store's verified decode path
    let warm = solve_with(&[
        "-model",
        "replacement",
        "-num_states",
        "30",
        "-serve_store",
        store,
        "-warm_start",
        fp.as_str(),
    ]);
    assert_eq!(warm.result.outer_iterations, 1);
    assert_eq!(bits(warm.value()), bits(cold.value()));
    assert_eq!(warm.warm_start.as_deref(), Some(fp.as_str()));

    // fingerprint without a store is a typed error, not a file-not-found
    let err = try_solve(&[
        "-model",
        "replacement",
        "-num_states",
        "30",
        "-warm_start",
        fp.as_str(),
    ])
    .unwrap_err();
    assert!(err.0.contains("-serve_store"), "{err}");

    // absent fingerprint is the store's typed not-found
    let err = try_solve(&[
        "-model",
        "replacement",
        "-num_states",
        "30",
        "-serve_store",
        store,
        "-warm_start",
        "ffffffffffffffff",
    ])
    .unwrap_err();
    assert!(err.0.contains("ffffffffffffffff"), "{err}");
}

fn chain_builder(n: usize) -> MdpBuilder {
    MdpBuilder::from_fillers(
        n,
        2,
        move |s, a| {
            if a == 1 {
                vec![(0, 1.0)]
            } else if s + 1 < n {
                vec![(s, 0.5), (s + 1, 0.5)]
            } else {
                vec![(s, 1.0)]
            }
        },
        |s, a| if a == 1 { 2.0 } else { s as f64 * 0.1 },
    )
    .gamma(0.9)
}

#[test]
fn builder_warm_start_seeds_in_process_and_conflicts_are_typed() {
    let cold = Solver::new(chain_builder(12)).solve().unwrap();

    // in-process seed: no checkpoint file involved
    let warm = Solver::new(chain_builder(12).warm_start(&cold))
        .solve()
        .unwrap();
    assert_eq!(warm.result.outer_iterations, 1);
    assert_eq!(bits(warm.value()), bits(cold.value()));
    assert_eq!(warm.policy(), cold.policy());
    assert_eq!(warm.warm_start.as_deref(), Some(cold.fingerprint().as_str()));

    // builder seed + -warm_start is one surface: setting both is a typed
    // conflict, mirroring the model-source rule
    let dir = tmp("conflict");
    let ck = dir.join("ck.mdpa");
    cold.write_checkpoint(&ck).unwrap();
    let mut solver = Solver::new(chain_builder(12).warm_start(&cold));
    solver.set_option("-warm_start", ck.to_str().unwrap()).unwrap();
    let err = solver.solve().unwrap_err();
    assert!(err.0.contains("conflicting warm-start sources"), "{err}");

    // an incompatible in-process seed is typed too
    let err = Solver::new(chain_builder(13).warm_start(&cold))
        .solve()
        .unwrap_err();
    assert!(err.0.contains("states"), "{err}");
}

#[test]
fn builder_patches_match_rebuilding_the_drifted_model() {
    // drift: jumping home gets cheaper, and state 2's drift row changes
    let patched = Solver::new(
        chain_builder(12)
            .patch_costs([(0, 1, 0.5)])
            .patch_transitions([(2, 0, vec![(2, 0.25), (3, 0.75)])]),
    )
    .solve()
    .unwrap();

    // the same drifted model built from scratch
    let n = 12usize;
    let scratch = Solver::new(
        MdpBuilder::from_fillers(
            n,
            2,
            move |s, a| {
                if a == 1 {
                    vec![(0, 1.0)]
                } else if s == 2 {
                    vec![(2, 0.25), (3, 0.75)]
                } else if s + 1 < n {
                    vec![(s, 0.5), (s + 1, 0.5)]
                } else {
                    vec![(s, 1.0)]
                }
            },
            |s, a| {
                if (s, a) == (0, 1) {
                    0.5
                } else if a == 1 {
                    2.0
                } else {
                    s as f64 * 0.1
                }
            },
        )
        .gamma(0.9),
    )
    .solve()
    .unwrap();

    assert!(patched.result.converged);
    assert_eq!(bits(patched.value()), bits(scratch.value()));
    assert_eq!(patched.policy(), scratch.policy());

    // distributed patched solve agrees with the serial one
    let mut dist = Solver::new(
        chain_builder(12)
            .patch_costs([(0, 1, 0.5)])
            .patch_transitions([(2, 0, vec![(2, 0.25), (3, 0.75)])]),
    );
    dist.set_option("-ranks", "3").unwrap();
    let dist = dist.solve().unwrap();
    madupite::util::prop::close_slices(dist.value(), patched.value(), 1e-9).unwrap();
    assert_eq!(dist.policy(), patched.policy());
}

#[test]
fn invalid_patches_are_typed_errors() {
    // sub-stochastic replacement row
    let err = Solver::new(chain_builder(8).patch_transitions([(1, 0, vec![(0, 0.4)])]))
        .solve()
        .unwrap_err();
    assert!(err.0.contains("sums to"), "{err}");

    // out-of-range cost entry
    let err = Solver::new(chain_builder(8).patch_costs([(8, 0, 1.0)]))
        .solve()
        .unwrap_err();
    assert!(err.0.contains("out of range"), "{err}");

    // non-finite cost
    let err = Solver::new(chain_builder(8).patch_costs([(1, 0, f64::NAN)]))
        .solve()
        .unwrap_err();
    assert!(err.0.contains("non-finite"), "{err}");
}

#[test]
fn prepared_model_never_reinvokes_fillers_after_build() {
    let n = 10usize;
    let prob_calls = Arc::new(AtomicUsize::new(0));
    let cost_calls = Arc::new(AtomicUsize::new(0));
    let (pc, cc) = (Arc::clone(&prob_calls), Arc::clone(&cost_calls));
    let builder = MdpBuilder::from_fillers(
        n,
        2,
        move |s, a| {
            pc.fetch_add(1, Ordering::Relaxed);
            if a == 1 {
                vec![(0, 1.0)]
            } else if s + 1 < n {
                vec![(s, 0.5), (s + 1, 0.5)]
            } else {
                vec![(s, 1.0)]
            }
        },
        move |s, a| {
            cc.fetch_add(1, Ordering::Relaxed);
            if a == 1 {
                2.0
            } else {
                s as f64 * 0.1
            }
        },
    )
    .gamma(0.9);

    let solver = Solver::new(builder);
    let mut prepared = solver.build().unwrap();
    let probs_after_build = prob_calls.load(Ordering::Relaxed);
    let costs_after_build = cost_calls.load(Ordering::Relaxed);
    assert!(probs_after_build >= n * 2, "build must realize every row");

    // patching touched rows and re-solving twice never re-invokes the
    // fillers: untouched rows are not re-scanned, touched rows are
    // validated from the patch data itself
    prepared.patch_costs(&[(0, 1, 0.25)]).unwrap();
    prepared
        .patch_transitions(&[(3, 0, vec![(3, 0.5), (4, 0.5)])])
        .unwrap();
    let a = solver.solve_prepared(&prepared).unwrap();
    let b = solver.solve_prepared(&prepared).unwrap();
    assert!(a.result.converged);
    assert_eq!(bits(a.value()), bits(b.value()));
    assert_eq!(prob_calls.load(Ordering::Relaxed), probs_after_build);
    assert_eq!(cost_calls.load(Ordering::Relaxed), costs_after_build);
}

#[test]
fn cli_checkpoint_roundtrip_is_byte_identical() {
    let dir = tmp("cli");
    let ck = dir.join("ck.mdpa");
    let p = |name: &str| dir.join(name).to_str().unwrap().to_string();
    let run = |extra: &[&str]| {
        let mut cmd = std::process::Command::new(env!("CARGO_BIN_EXE_madupite"));
        cmd.args(["solve", "-model", "maze", "-rows", "7", "-cols", "7"]);
        cmd.args(extra);
        let out = cmd.output().unwrap();
        assert!(
            out.status.success(),
            "stderr: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        String::from_utf8(out.stdout).unwrap()
    };

    let cold_out = run(&[
        "-write_checkpoint",
        ck.to_str().unwrap(),
        "-write_cost",
        &p("v1.txt"),
        "-write_policy",
        &p("p1.txt"),
        "-write_json_metadata",
        &p("m1.json"),
    ]);
    assert!(
        cold_out.contains(&format!("wrote {}", ck.display())),
        "{cold_out}"
    );

    run(&[
        "-warm_start",
        ck.to_str().unwrap(),
        "-write_cost",
        &p("v2.txt"),
        "-write_policy",
        &p("p2.txt"),
        "-write_json_metadata",
        &p("m2.json"),
    ]);

    // warm outputs are byte-identical to cold
    assert_eq!(
        std::fs::read(p("v1.txt")).unwrap(),
        std::fs::read(p("v2.txt")).unwrap()
    );
    assert_eq!(
        std::fs::read(p("p1.txt")).unwrap(),
        std::fs::read(p("p2.txt")).unwrap()
    );

    // metadata: provenance only on the warm run, one outer iteration
    let m1 = Json::parse(&std::fs::read_to_string(p("m1.json")).unwrap()).unwrap();
    let m2 = Json::parse(&std::fs::read_to_string(p("m2.json")).unwrap()).unwrap();
    assert!(m1.get("solver").unwrap().get("warm_start").is_none());
    assert!(m2
        .get("solver")
        .unwrap()
        .get("warm_start")
        .and_then(Json::as_str)
        .is_some());
    assert_eq!(
        m2.get("result")
            .unwrap()
            .get("outer_iterations")
            .unwrap()
            .as_f64(),
        Some(1.0)
    );
    assert!(
        m1.get("result")
            .unwrap()
            .get("outer_iterations")
            .unwrap()
            .as_f64()
            .unwrap()
            > 1.0
    );
}
