//! Catalog-wide model validation: every entry of `MODEL_CATALOG` is
//! instantiated (at test-sized parameters) and held to the generator
//! contract — stochastic rows, in-range successors, finite costs, valid
//! per-(s, a) discounts — plus a small solve as an objective sanity check.
//!
//! The parameter table below is *deliberately* exhaustive over the
//! catalog: a model added to `MODEL_CATALOG` without a matching arm here
//! panics loudly, naming the uncovered model, so catalog growth can never
//! silently escape validation.

use madupite::api::{model_from_options, MODEL_CATALOG};
use madupite::models::ModelGenerator;
use madupite::solver::{solve_serial, Method, SolveOptions};
use madupite::util::args::Options;
use std::sync::Arc;

fn db(toks: &[&str]) -> Options {
    Options::parse(toks.iter().map(|s| s.to_string()))
}

/// Small instantiation parameters per catalog model, so the exhaustive
/// row sweep stays test-sized. The catch-all arm is the coverage gate.
fn small_params(name: &str) -> Vec<&'static str> {
    match name {
        "maze" | "grid" => vec!["-rows", "5", "-cols", "5"],
        "sis" => vec!["-population", "40", "-num_actions", "3"],
        "traffic" => vec!["-capacity", "5"],
        "garnet" => vec!["-num_states", "60", "-num_actions", "3", "-branching", "4"],
        "inventory" => vec!["-capacity", "12"],
        "queueing" => vec!["-capacity", "12"],
        "replacement" => vec!["-num_states", "12"],
        "maintenance" => vec!["-num_states", "12"],
        "sis_factored" => vec!["-population", "5"],
        "factory" => vec!["-machines", "3"],
        other => panic!(
            "MODEL_CATALOG gained '{other}' but tests/models.rs has no \
             small-instance parameters for it — add an arm to small_params \
             so catalog-wide validation covers every model"
        ),
    }
}

fn instantiate(name: &str) -> Arc<dyn ModelGenerator + Send + Sync> {
    model_from_options(name, &db(&small_params(name)))
        .unwrap_or_else(|e| panic!("{name}: small instance failed to build: {e}"))
}

/// Row-level contract on every catalog model: every `(s, a)` row is a
/// probability distribution (1e-8), targets in range, costs finite, and
/// the effective discount stays in [0, 1) at representative base gammas.
#[test]
fn every_catalog_model_satisfies_the_generator_contract() {
    for info in MODEL_CATALOG {
        let g = instantiate(info.name);
        let (n, m) = (g.n_states(), g.n_actions());
        assert!(n > 0, "{}: no states", info.name);
        assert!(m >= 1, "{}: no actions", info.name);
        for s in 0..n {
            for a in 0..m {
                let row = g.prob_row(s, a);
                assert!(!row.is_empty(), "{}: empty row at ({s},{a})", info.name);
                let mut sum = 0.0;
                for &(t, p) in &row {
                    assert!(
                        t < n,
                        "{}: successor {t} out of range at ({s},{a})",
                        info.name
                    );
                    assert!(
                        p.is_finite() && (0.0..=1.0 + 1e-12).contains(&p),
                        "{}: bad probability {p} at ({s},{a})",
                        info.name
                    );
                    sum += p;
                }
                assert!(
                    (sum - 1.0).abs() < 1e-8,
                    "{}: row ({s},{a}) sums to {sum}, not 1 (tol 1e-8)",
                    info.name
                );
                let c = g.cost(s, a);
                assert!(c.is_finite(), "{}: non-finite cost at ({s},{a})", info.name);
                for gamma in [0.5, 0.99] {
                    let d = g.discount(s, a, gamma);
                    assert!(
                        d.is_finite() && (0.0..1.0).contains(&d),
                        "{}: discount {d} outside [0, 1) at ({s},{a}), gamma {gamma}",
                        info.name
                    );
                    if !g.has_discounts() {
                        assert_eq!(
                            d, gamma,
                            "{}: claims no per-(s,a) discounts but returned {d} != {gamma}",
                            info.name
                        );
                    }
                }
            }
        }
    }
}

/// Objective sanity: every catalog model solves at its small size, and
/// the minimized value at every state is a lower bound on the maximized
/// one (costs are not all equal across policies for any catalog model).
#[test]
fn every_catalog_model_solves_both_objectives() {
    use madupite::mdp::Objective;
    for info in MODEL_CATALOG {
        let g = instantiate(info.name);
        let opts = SolveOptions {
            method: Method::Vi,
            atol: 1e-8,
            max_outer: 100_000,
            ..Default::default()
        };
        let base = g
            .try_build_serial(0.9)
            .unwrap_or_else(|e| panic!("{}: build failed: {e}", info.name));
        let min = solve_serial(&base, &opts);
        assert!(min.converged, "{}: min solve did not converge", info.name);
        let max = solve_serial(
            &g.try_build_serial(0.9).unwrap().with_objective(Objective::Max),
            &opts,
        );
        assert!(max.converged, "{}: max solve did not converge", info.name);
        for s in 0..g.n_states() {
            assert!(
                min.value[s].is_finite() && max.value[s].is_finite(),
                "{}: non-finite value at {s}",
                info.name
            );
            assert!(
                min.value[s] <= max.value[s] + 1e-7,
                "{}: min value {} exceeds max value {} at state {s}",
                info.name,
                min.value[s],
                max.value[s]
            );
        }
    }
}

/// The catalog itself is well-formed: unique names, non-empty help text,
/// and the factored entries the docs promise are present.
#[test]
fn catalog_is_well_formed_and_lists_the_factored_models() {
    let names: Vec<&str> = MODEL_CATALOG.iter().map(|m| m.name).collect();
    let mut deduped = names.clone();
    deduped.sort_unstable();
    deduped.dedup();
    assert_eq!(deduped.len(), names.len(), "duplicate catalog names");
    for info in MODEL_CATALOG {
        assert!(!info.about.is_empty(), "{}: empty about", info.name);
        assert!(!info.params.is_empty(), "{}: empty params", info.name);
    }
    assert!(names.contains(&"sis_factored"));
    assert!(names.contains(&"factory"));
}

/// The coverage gate fires: a name outside the catalog (as would appear
/// if `MODEL_CATALOG` grew without this file keeping up) panics with an
/// actionable message naming the model.
#[test]
#[should_panic(expected = "small-instance parameters")]
fn uncovered_catalog_entries_panic_loudly() {
    let _ = small_params("brand_new_model");
}
