//! Documentation parity gates.
//!
//! The narrative guide (`docs/guide.md`) is doctested via the
//! `madupite::docs::guide` module, so its code cannot rot; this suite
//! pins the *prose* against the code the same way:
//!
//! - the guide's options-reference table must list exactly the keys of
//!   `OPTION_TABLE` (a new `-flag` cannot ship undocumented, a removed
//!   one cannot linger in the docs);
//! - the generated `madupite help` output must cover the same keys and
//!   every model-catalog entry (help is generated from the table, so this
//!   pins the whole chain guide ↔ table ↔ help);
//! - README.md must mention every catalog model and link the guide.

use madupite::api::options::OPTION_TABLE;
use madupite::api::MODEL_CATALOG;
use std::collections::BTreeSet;

fn repo_file(rel: &str) -> String {
    let path = format!("{}/../{rel}", env!("CARGO_MANIFEST_DIR"));
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {path}: {e}"))
}

/// The `-key` cells of the guide's "Options reference" table.
fn guide_option_keys() -> BTreeSet<String> {
    let guide = repo_file("docs/guide.md");
    let section = guide
        .split("## Options reference")
        .nth(1)
        .expect("docs/guide.md must keep its '## Options reference' section");
    let section = section.split("\n## ").next().unwrap();
    let mut keys = BTreeSet::new();
    for line in section.lines() {
        let line = line.trim();
        if let Some(rest) = line.strip_prefix("| `-") {
            let key = rest
                .split('`')
                .next()
                .expect("table row must close its backtick");
            keys.insert(key.to_string());
        }
    }
    keys
}

#[test]
fn guide_table_matches_option_table() {
    let documented = guide_option_keys();
    let actual: BTreeSet<String> = OPTION_TABLE.iter().map(|s| s.key.to_string()).collect();
    let missing: Vec<_> = actual.difference(&documented).collect();
    let stale: Vec<_> = documented.difference(&actual).collect();
    assert!(
        missing.is_empty() && stale.is_empty(),
        "docs/guide.md options table drifted from OPTION_TABLE: \
         undocumented {missing:?}, stale {stale:?}"
    );
}

#[test]
fn generated_help_covers_table_and_catalog() {
    let exe = env!("CARGO_BIN_EXE_madupite");
    let out = std::process::Command::new(exe)
        .arg("help")
        .output()
        .unwrap();
    assert!(out.status.success());
    let help = String::from_utf8_lossy(&out.stdout);
    for spec in OPTION_TABLE {
        assert!(
            help.contains(&format!("-{}", spec.key)),
            "help output is missing -{}",
            spec.key
        );
    }
    for model in MODEL_CATALOG {
        assert!(
            help.contains(model.name),
            "help output is missing model '{}'",
            model.name
        );
    }
}

#[test]
fn guide_documents_every_model_dimension() {
    let guide = repo_file("docs/guide.md");
    // the semi-MDP chapter is the load-bearing narrative of the
    // generalized-discounting layer — keep its anchors present
    for needle in [
        "Beyond scalar discounting",
        "maintenance",
        "discount_filler",
        "per_state_action",
    ] {
        assert!(guide.contains(needle), "guide lost its '{needle}' chapter");
    }
}

#[test]
fn readme_mentions_catalog_and_guide() {
    let readme = repo_file("README.md");
    for model in MODEL_CATALOG {
        assert!(
            readme.contains(model.name),
            "README model catalog is missing '{}'",
            model.name
        );
    }
    assert!(
        readme.contains("docs/guide.md"),
        "README must link the user guide"
    );
}
