//! Generalized-discounting (semi-MDP) test suite — DESIGN.md §12.
//!
//! Pins the load-bearing invariants of the `Discount` layer:
//!
//! - **Representation invariance**: `Discount::Scalar(g)` and a constant
//!   per-state / per-state-action vector filled with `g` produce **bitwise
//!   identical** values, policies and residual traces across the full
//!   method × eval-backend × ranks × threads matrix.
//! - **Offline format**: `.mdpb` v3 round-trips the discount payload
//!   through the serial, distributed and streaming writers (byte-identical
//!   files for every world size); v1/v2 files keep loading.
//! - **Typed-error surface**: out-of-range / wrong-length / non-finite
//!   discounts and conflicting `-discount_mode` combinations are errors
//!   with the offending entry named — never panics or deadlocks.
//! - **Semi-MDP semantics**: a hand-computed two-state fixture shows the
//!   per-transition discount flipping the optimal policy relative to any
//!   scalar collapse, and the `maintenance` catalog model solves end to
//!   end (model → solve, model → .mdpb → solve).

use madupite::api::{self, MdpBuilder, Solver};
use madupite::comm::World;
use madupite::mdp::{io, Discount, DiscountMode, Mdp};
use madupite::models::{garnet::GarnetSpec, maintenance::MaintenanceSpec, ModelGenerator};
use madupite::solver::{solve_world, EvalBackend, Method, SolveOptions, SolveResult};
use madupite::util::args::Options;
use madupite::util::par;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// `par::set_threads` is process-global; tests that sweep it serialize on
/// this lock.
static THREADS_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    THREADS_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn tmpfile(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("madupite_discount_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{}_{name}", std::process::id()))
}

fn db(toks: &[&str]) -> Options {
    Options::parse(toks.iter().map(|s| s.to_string()))
}

/// Exact-bits fingerprint of everything the discount representation must
/// not change: values, policy, counters, residual trace.
#[allow(clippy::type_complexity)]
fn fingerprint(r: &SolveResult) -> (Vec<u64>, Vec<usize>, bool, usize, Vec<(u64, usize)>) {
    (
        r.value.iter().map(|v| v.to_bits()).collect(),
        r.policy.clone(),
        r.converged,
        r.outer_iterations,
        r.trace
            .iter()
            .map(|t| (t.residual.to_bits(), t.inner_iterations))
            .collect(),
    )
}

fn methods() -> Vec<Method> {
    vec![
        Method::Vi,
        Method::Mpi { sweeps: 5 },
        Method::ExactPi,
        Method::ipi_gmres(),
        Method::ipi_bicgstab(),
        Method::ipi_tfqmr(),
    ]
}

/// The acceptance invariant: a constant discount vector (either shape) is
/// bitwise indistinguishable from the scalar, for every method, both
/// evaluation backends, serial and multi-rank worlds, and thread counts
/// 1 and 4.
#[test]
fn scalar_equals_constant_vector_bitwise() {
    let _guard = lock();
    let (n, m, g) = (40usize, 3usize, 0.95);
    let scalar = Arc::new(GarnetSpec::new(n, m, 4, 7).build_serial(g));
    for mode in [DiscountMode::PerState, DiscountMode::PerStateAction] {
        let vector = Arc::new(
            Mdp::new_discounted(
                n,
                m,
                scalar.transitions().clone(),
                scalar.costs().to_vec(),
                Discount::constant(mode, g, n, m),
            )
            .unwrap(),
        );
        assert_eq!(vector.gamma(), g, "constant bound collapses to the scalar");
        for method in methods() {
            for backend in [EvalBackend::MatFree, EvalBackend::Assembled] {
                for ranks in [1usize, 3] {
                    for threads in [1usize, 4] {
                        par::set_threads(threads);
                        let opts = SolveOptions {
                            method: method.clone(),
                            eval_backend: backend,
                            atol: 1e-9,
                            ..Default::default()
                        };
                        let a = solve_world(Arc::clone(&scalar), ranks, &opts);
                        let b = solve_world(Arc::clone(&vector), ranks, &opts);
                        assert!(a.converged, "{}", method.name());
                        assert_eq!(
                            fingerprint(&a),
                            fingerprint(&b),
                            "{:?}/{}/{}/ranks={ranks}/threads={threads} diverged",
                            mode,
                            method.name(),
                            backend.name()
                        );
                    }
                }
            }
        }
    }
    par::set_threads(1);
}

/// The same invariance holds through the options database: forcing
/// `-discount_mode per_state(_action)` on a scalar catalog model solves
/// bitwise identically to the plain scalar run.
#[test]
fn forced_discount_mode_matches_scalar_through_api() {
    let _guard = lock();
    par::set_threads(1);
    let run = |mode: &str| {
        let params = db(&["-num_states", "60", "-seed", "3"]);
        let builder = MdpBuilder::from_model_name("garnet", &params).unwrap();
        let mut solver = Solver::with_database(builder, params);
        solver
            .set_options_from_str("-gamma 0.95 -method ipi -ksp_type gmres -atol 1e-9 -ranks 2")
            .unwrap();
        if mode != "auto" {
            solver.set_option("-discount_mode", mode).unwrap();
        }
        solver.solve().unwrap()
    };
    let base = run("auto");
    assert_eq!(base.discount_mode, DiscountMode::Scalar);
    for mode in ["scalar", "per_state", "per_state_action"] {
        let forced = run(mode);
        assert_eq!(
            forced.discount_mode,
            DiscountMode::parse(mode).unwrap(),
            "-discount_mode {mode}"
        );
        assert_eq!(forced.policy(), base.policy(), "-discount_mode {mode}");
        for (a, b) in base.value().iter().zip(forced.value()) {
            assert_eq!(a.to_bits(), b.to_bits(), "-discount_mode {mode}");
        }
        assert_eq!(forced.gamma, base.gamma);
    }
}

/// Hand-computed two-state semi-MDP: per-action discounts flip the optimal
/// policy relative to the scalar collapse.
///
/// State 1 absorbs at cost 0. From state 0: action 0 self-loops at cost 1
/// with γ(0,0) = 0.5 → staying forever costs 1/(1−0.5) = 2; action 1 jumps
/// to the absorbing state at cost 3 with γ(0,1) = 0.9 → total 3. So the
/// semi-MDP optimum is *stay* (V*(0) = 2), while collapsing to the scalar
/// bound γ̄ = 0.9 makes staying cost 1/(1−0.9) = 10 and flips the optimum
/// to *jump* (V*(0) = 3). One scalar cannot represent this model.
#[test]
fn semi_mdp_fixture_flips_policy_vs_scalar() {
    let prob = |s: usize, a: usize| match (s, a) {
        (0, 0) => vec![(0, 1.0)],
        (0, 1) => vec![(1, 1.0)],
        _ => vec![(1, 1.0)],
    };
    let cost = |s: usize, a: usize| match (s, a) {
        (0, 0) => 1.0,
        (0, 1) => 3.0,
        _ => 0.0,
    };
    let disc = |s: usize, a: usize| match (s, a) {
        (0, 0) => 0.5,
        (0, 1) => 0.9,
        _ => 0.5,
    };
    let semi = Mdp::try_from_fillers_semi(2, 2, disc, prob, cost).unwrap();
    assert_eq!(semi.gamma(), 0.9, "bound is the max entry");
    let scalar = Mdp::try_from_fillers(2, 2, 0.9, prob, cost).unwrap();

    for method in methods() {
        let opts = SolveOptions {
            method: method.clone(),
            atol: 1e-11,
            ..Default::default()
        };
        let rs = solve_world(Arc::new(semi.clone()), 1, &opts);
        assert!(rs.converged, "{}", method.name());
        assert_eq!(rs.policy[0], 0, "{}: semi-MDP stays", method.name());
        assert!((rs.value[0] - 2.0).abs() < 1e-8, "{}", method.name());
        assert!(rs.value[1].abs() < 1e-8);

        let rc = solve_world(Arc::new(scalar.clone()), 1, &opts);
        assert!(rc.converged);
        assert_eq!(rc.policy[0], 1, "{}: scalar collapse jumps", method.name());
        assert!((rc.value[0] - 3.0).abs() < 1e-8);
    }

    // ...and the same fixture through the builder's discount_filler, on
    // serial and multi-rank worlds (rank-local validation + collective
    // agreement under the hood).
    for ranks in ["1", "3"] {
        let builder = MdpBuilder::from_fillers(2, 2, prob, cost).discount_filler(disc);
        let mut solver = Solver::new(builder);
        solver
            .set_options_from_str("-method ipi -atol 1e-11")
            .unwrap();
        solver.set_option("-ranks", ranks).unwrap();
        let outcome = solver.solve().unwrap();
        assert_eq!(outcome.discount_mode, DiscountMode::PerStateAction);
        assert_eq!(outcome.policy()[0], 0, "ranks={ranks}");
        assert!((outcome.value()[0] - 2.0).abs() < 1e-8, "ranks={ranks}");
        assert_eq!(outcome.gamma, 0.9);
    }
}

/// `.mdpb` v3 round-trips the discount payload: serial save/load, and the
/// distributed reader slices the vector per rank.
#[test]
fn mdpb_v3_roundtrips_discount_payload() {
    let spec = MaintenanceSpec::standard(17);
    let semi = spec.build_serial(0.9);
    assert_eq!(semi.discount().mode(), DiscountMode::PerStateAction);
    let path = tmpfile("maintenance_v3.mdpb");
    io::save(&semi, &path).unwrap();

    // header carries mode + bound
    let mut f = std::fs::File::open(&path).unwrap();
    let h = io::read_header(&mut f).unwrap();
    assert_eq!(h.version, io::VERSION);
    assert_eq!(h.discount_mode, DiscountMode::PerStateAction);
    assert_eq!(h.gamma, semi.gamma());

    // serial reader restores the exact discount vector
    let loaded = io::load(&path).unwrap();
    assert_eq!(loaded.discount(), semi.discount());
    let v0 = vec![0.0; 17];
    let (tv0, pol0) = semi.bellman(&v0);
    let (tv1, pol1) = loaded.bellman(&v0);
    assert_eq!(pol0, pol1);
    for (a, b) in tv0.iter().zip(&tv1) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    // distributed reader: each rank holds its slice; solves agree with the
    // serial model at every world size
    let opts = SolveOptions {
        method: Method::ipi_gmres(),
        atol: 1e-10,
        ..Default::default()
    };
    let serial = solve_world(Arc::new(semi.clone()), 1, &opts);
    for ranks in [1usize, 3] {
        let p = path.clone();
        let o = opts.clone();
        let out = World::run(ranks, move |comm| {
            let d = io::load_dist(&comm, &p).unwrap();
            assert_eq!(d.discount().mode(), DiscountMode::PerStateAction);
            let local = madupite::solver::solve_dist(&comm, &d, &o);
            madupite::solver::gather_result(&comm, local)
        });
        assert_eq!(out[0].policy, serial.policy, "ranks={ranks}");
        for (a, b) in out[0].value.iter().zip(&serial.value) {
            assert!((a - b).abs() < 1e-8, "ranks={ranks}: {a} vs {b}");
        }
        assert_eq!(out[0].gamma, serial.gamma, "ranks={ranks}");
    }

    // v3 without a payload: scalar files still declare mode scalar and
    // carry no trailing section
    let scalar = GarnetSpec::new(9, 2, 3, 1).build_serial(0.8);
    let spath = tmpfile("scalar_v3.mdpb");
    io::save(&scalar, &spath).unwrap();
    let mut f = std::fs::File::open(&spath).unwrap();
    let hs = io::read_header(&mut f).unwrap();
    assert_eq!(hs.discount_mode, DiscountMode::Scalar);
    assert_eq!(
        hs.expected_file_len(),
        std::fs::metadata(&spath).unwrap().len() as u128
    );
    assert_eq!(io::load(&spath).unwrap().discount(), &Discount::Scalar(0.8));
}

/// All three v3 producers — serial save, rank-parallel save_dist, and the
/// two-pass streaming writer — emit byte-identical files for a semi-MDP,
/// at every world size.
#[test]
fn v3_writers_byte_identical_across_ranks() {
    let spec = Arc::new(MaintenanceSpec::standard(23));
    let gamma = 0.93;
    let ref_path = tmpfile("semi_ref.mdpb");
    io::save(&spec.build_serial(gamma), &ref_path).unwrap();
    let want = std::fs::read(&ref_path).unwrap();

    for ranks in [1usize, 2, 3] {
        // streaming writer (generate path), deliberately odd chunk size
        let stream_path = tmpfile(&format!("semi_stream_r{ranks}.mdpb"));
        let spec2 = Arc::clone(&spec);
        let p = stream_path.clone();
        World::run(ranks, move |comm| {
            spec2
                .write_mdpb(&comm, gamma, madupite::mdp::Objective::Min, &p, 5)
                .unwrap();
        });
        assert!(
            std::fs::read(&stream_path).unwrap() == want,
            "ranks={ranks}: streamed bytes differ"
        );

        // save_dist (load_dist → write back)
        let dist_path = tmpfile(&format!("semi_dist_r{ranks}.mdpb"));
        let rp = ref_path.clone();
        let dp = dist_path.clone();
        World::run(ranks, move |comm| {
            let d = io::load_dist(&comm, &rp).unwrap();
            io::save_dist(&comm, &d, &dp).unwrap();
        });
        assert!(
            std::fs::read(&dist_path).unwrap() == want,
            "ranks={ranks}: save_dist bytes differ"
        );
    }
}

/// A forced constant payload (`write_streaming_constant` — the generate
/// command's `-discount_mode` expansion) loads back as the constant vector
/// and solves bitwise identically to the scalar file.
#[test]
fn constant_streamed_payload_matches_scalar() {
    let spec = Arc::new(GarnetSpec::new(30, 2, 3, 9));
    let scalar_path = tmpfile("const_scalar.mdpb");
    let psa_path = tmpfile("const_psa.mdpb");
    for (path, mode) in [
        (scalar_path.clone(), DiscountMode::Scalar),
        (psa_path.clone(), DiscountMode::PerStateAction),
    ] {
        let s2 = Arc::clone(&spec);
        World::run(2, move |comm| {
            io::write_streaming_constant(
                &comm,
                &path,
                s2.n_states(),
                s2.n_actions(),
                mode,
                0.9,
                madupite::mdp::Objective::Min,
                7,
                |s, a| s2.prob_row(s, a),
                |s, a| s2.cost(s, a),
            )
            .unwrap();
        });
    }
    let a = io::load(&scalar_path).unwrap();
    let b = io::load(&psa_path).unwrap();
    assert_eq!(a.discount(), &Discount::Scalar(0.9));
    assert_eq!(b.discount(), &Discount::PerStateAction(vec![0.9; 60]));
    let opts = SolveOptions {
        atol: 1e-9,
        ..Default::default()
    };
    let ra = solve_world(Arc::new(a), 1, &opts);
    let rb = solve_world(Arc::new(b), 1, &opts);
    assert_eq!(fingerprint(&ra), fingerprint(&rb));
}

/// Typed-error surface: bad vector discounts are errors with the offending
/// entry named, everywhere they can enter — constructors, fillers, the
/// options database, and distributed builds (collective agreement, no
/// deadlock).
#[test]
fn bad_discounts_are_typed_errors() {
    let t = |n: usize| GarnetSpec::new(n, 2, 2, 5).build_serial(0.9);

    // wrong length
    let m9 = t(9);
    let err = Mdp::new_discounted(
        9,
        2,
        m9.transitions().clone(),
        m9.costs().to_vec(),
        Discount::PerStateAction(vec![0.9; 5]),
    )
    .unwrap_err();
    assert!(err.contains("5 entries"), "{err}");

    // out of range, entry named
    let mut v = vec![0.5; 18];
    v[7] = 1.0;
    let err = Mdp::new_discounted(
        9,
        2,
        m9.transitions().clone(),
        m9.costs().to_vec(),
        Discount::PerStateAction(v),
    )
    .unwrap_err();
    assert!(err.contains("s=3, a=1"), "{err}");

    // non-finite through the serial filler
    let err = Mdp::try_from_fillers_semi(
        4,
        1,
        |s, _| if s == 2 { f64::NAN } else { 0.9 },
        |s, _| vec![(s, 1.0)],
        |_, _| 1.0,
    )
    .unwrap_err();
    assert!(err.contains("s=2"), "{err}");

    // distributed: the bad entry lives on the last rank only — every rank
    // must error (agreement), not deadlock or panic
    for ranks in ["1", "3"] {
        let builder = MdpBuilder::from_fillers(30, 1, |s, _| vec![(s, 1.0)], |_, _| 1.0)
            .discount_filler(|s, _| if s == 29 { 1.5 } else { 0.9 });
        let mut solver = Solver::new(builder);
        solver.set_option("-ranks", ranks).unwrap();
        let err = solver.solve().unwrap_err();
        assert!(err.0.contains("s=29"), "ranks={ranks}: {err}");
    }

    // options-database surface: typo'd value gets a did-you-mean; file
    // sources reject -discount_mode; semi models reject narrowing; a
    // scalar gamma conflicts with a discount filler
    let mut s = Solver::new(MdpBuilder::from_model_name("garnet", &db(&[])).unwrap());
    s.set_option("-discount_mode", "per_stat").unwrap();
    let err = s.solve().unwrap_err();
    assert!(err.0.contains("per_state"), "{err}");

    let mut s = Solver::new(MdpBuilder::from_file("x.mdpb"));
    s.set_option("-discount_mode", "scalar").unwrap();
    let err = s.solve().unwrap_err();
    assert!(err.0.contains("header"), "{err}");

    let mut s = Solver::new(MdpBuilder::from_model_name("maintenance", &db(&[])).unwrap());
    s.set_option("-discount_mode", "scalar").unwrap();
    let err = s.solve().unwrap_err();
    assert!(err.0.contains("semi-MDP"), "{err}");

    let builder = MdpBuilder::from_fillers(2, 1, |s, _| vec![(s, 1.0)], |_, _| 1.0)
        .discount_filler(|_, _| 0.9)
        .gamma(0.5);
    let err = Solver::new(builder).solve().unwrap_err();
    assert!(err.0.contains("conflicts"), "{err}");
}

/// The maintenance catalog model is reachable end to end from the options
/// database, and the offline pipeline (generate → solve-from-file) agrees
/// with the direct model solve.
#[test]
fn maintenance_model_end_to_end() {
    let params = db(&["-num_states", "20"]);
    let builder = MdpBuilder::from_model_name("maintenance", &params).unwrap();
    let mut solver = Solver::with_database(builder, params);
    solver
        .set_options_from_str("-gamma 0.95 -method ipi -ksp_type gmres -atol 1e-9 -ranks 2")
        .unwrap();
    let direct = solver.solve().unwrap();
    assert!(direct.result.converged);
    assert_eq!(direct.discount_mode, DiscountMode::PerStateAction);
    assert_eq!(direct.policy().len(), 20);

    // offline: stream the same model to disk, solve from the file
    let path = tmpfile("maintenance_pipeline.mdpb");
    let spec = Arc::new(MaintenanceSpec::standard(20));
    let p = path.clone();
    let spec2 = Arc::clone(&spec);
    World::run(2, move |comm| {
        spec2
            .write_mdpb(
                &comm,
                0.95,
                madupite::mdp::Objective::Min,
                &p,
                io::DEFAULT_CHUNK_ROWS,
            )
            .unwrap();
    });
    let mut from_file = Solver::new(MdpBuilder::from_file(path.to_str().unwrap()));
    from_file
        .set_options_from_str("-method ipi -ksp_type gmres -atol 1e-9 -ranks 2")
        .unwrap();
    let offline = from_file.solve().unwrap();
    assert_eq!(offline.discount_mode, DiscountMode::PerStateAction);
    assert_eq!(offline.policy(), direct.policy());
    for (a, b) in offline.value().iter().zip(direct.value()) {
        assert!((a - b).abs() < 1e-8, "{a} vs {b}");
    }

    // metadata reports the discount mode
    let j = direct.metadata_json();
    assert_eq!(
        j.get("model")
            .unwrap()
            .get("discount_mode")
            .unwrap()
            .as_str(),
        Some("per_state_action")
    );
    let _ = api::MODEL_CATALOG
        .iter()
        .find(|m| m.name == "maintenance")
        .expect("maintenance is in the catalog");
}
