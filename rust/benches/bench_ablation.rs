//! E7 — Ablations of madupite-rs design choices (DESIGN.md §6 extension):
//!
//! 1. **Adaptive vs fixed forcing term** on a wavefront-limited maze —
//!    fixed tight α wastes inner iterations while the policy front moves;
//!    the Eisenstat–Walker adaptation detects the stalled outer contraction
//!    and loosens automatically.
//! 2. **Policy-system cache** (reuse `P_π` when the greedy policy did not
//!    change) — measured by solving with a method whose policy freezes
//!    early (iPI at tight tolerance).
//! 3. **Ghost-plan exchange vs full allgather** — communication volume of
//!    the precomputed VecScatter-style plan against the naive "replicate V
//!    everywhere" alternative, on the scaling maze.
//! 4. **Matrix-free vs assembled policy evaluation** — the `-eval_backend`
//!    knob: fused application off the stacked kernel vs materializing (and
//!    caching) an explicit `P_π` CSR per policy change. Reports per-rank
//!    resident transition bytes and per-outer-iteration setup time (both
//!    must be lower matrix-free) alongside end-to-end solve cost.

use madupite::comm::World;
use madupite::ksp::Apply;
use madupite::mdp::MatFreePolicyOp;
use madupite::models::{garnet::GarnetSpec, gridworld::GridSpec, ModelGenerator};
use madupite::solver::{
    gather_result, solve_dist, solve_serial, EvalBackend, Method, SolveOptions,
};
use madupite::util::benchkit::Suite;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let mut suite = Suite::new("E7 ablations");

    // --- 1. forcing-term adaptation on the wavefront workload --------------
    let maze = GridSpec::maze(100, 100, 21).build_serial(0.99);
    for (label, alpha, adaptive) in [
        ("fixed alpha=1e-4", 1e-4, false),
        ("fixed alpha=1e-2", 1e-2, false),
        ("adaptive (EW)", 1e-4, true),
    ] {
        let opts = SolveOptions {
            method: Method::ipi_gmres(),
            atol: 1e-8,
            alpha,
            adaptive_forcing: adaptive,
            max_outer: 100_000,
            ..Default::default()
        };
        suite.case(&format!("forcing/{label}"), || {
            let r = solve_serial(&maze, &opts);
            assert!(r.converged);
            vec![
                ("outer".to_string(), r.outer_iterations as f64),
                ("spmvs".to_string(), r.total_spmvs as f64),
            ]
        });
    }

    // --- 2. ghost-plan vs naive full allgather ------------------------------
    // The plan's cost is measured by the solver's total comm bytes; the
    // naive alternative is computed analytically: every SpMV would move the
    // full V (n·8 bytes) to every rank → spmvs × (R−1) × n × 8.
    let spec = Arc::new(GridSpec::maze(256, 256, 9));
    for ranks in [2usize, 4] {
        let spec2 = Arc::clone(&spec);
        suite.case(&format!("ghost-plan/ranks={ranks}"), move || {
            let spec3 = Arc::clone(&spec2);
            let opts = SolveOptions {
                method: Method::ipi_gmres(),
                atol: 1e-8,
                alpha: 1e-2,
                max_outer: 100_000,
                ..Default::default()
            };
            let mut out = World::run(ranks, move |comm| {
                let mdp = spec3.build_dist(&comm, 0.9);
                let local = solve_dist(&comm, &mdp, &opts);
                let bytes = comm.stats().snapshot().total_bytes();
                let r = gather_result(&comm, local);
                (r, bytes)
            });
            let (r, bytes) = out.swap_remove(0);
            assert!(r.converged);
            let n = 256 * 256;
            let naive = r.total_spmvs as f64 * (ranks - 1) as f64 * n as f64 * 8.0;
            vec![
                ("plan_MiB".to_string(), bytes as f64 / (1 << 20) as f64),
                ("naive_MiB".to_string(), naive / (1 << 20) as f64),
                (
                    "saving_x".to_string(),
                    naive / bytes.max(1) as f64,
                ),
            ]
        });
    }

    // --- 3. warm start vs cold start (v0 reuse across related solves) ------
    let garnet = madupite::models::garnet::GarnetSpec::new(20_000, 4, 5, 3).build_serial(0.99);
    let warm_v0 = solve_serial(
        &garnet,
        &SolveOptions {
            atol: 1e-4,
            ..Default::default()
        },
    )
    .value;
    for (label, v0) in [("cold", None), ("warm(coarse V)", Some(warm_v0.clone()))] {
        let opts = SolveOptions {
            method: Method::ipi_gmres(),
            atol: 1e-9,
            v0: v0.clone(),
            ..Default::default()
        };
        suite.case(&format!("warmstart/{label}"), || {
            let r = solve_serial(&garnet, &opts);
            assert!(r.converged);
            vec![("spmvs".to_string(), r.total_spmvs as f64)]
        });
    }

    // --- 4. matrix-free vs assembled policy evaluation ----------------------
    // (a) per-policy-change setup time and per-rank resident transition
    // bytes, measured directly on one distributed world;
    let eval_spec = Arc::new(GarnetSpec::new(100_000, 4, 5, 21));
    {
        let spec2 = Arc::clone(&eval_spec);
        suite.case("eval-backend/setup+memory", move || {
            let spec3 = Arc::clone(&spec2);
            let mut out = World::run(2, move |comm| {
                let mdp = spec3.build_dist(&comm, 0.99);
                let nl = mdp.local_states();
                let policy: Vec<usize> = (0..nl).map(|s| s % mdp.n_actions()).collect();

                let t0 = Instant::now();
                let (p_pi, _g) = mdp.policy_system(&comm, &policy);
                let assembled_setup = t0.elapsed().as_secs_f64();
                // resident = base kernel + the backend's extra state: the
                // P_π CSR copy and its own ghost buffer (assembled) vs only
                // the stacked matrix's ghost buffer (matrix-free).
                let assembled_resident = mdp.storage_bytes()
                    + p_pi.local().storage_bytes()
                    + p_pi.make_buffer().x().len() * 8;

                let t0 = Instant::now();
                let op = MatFreePolicyOp::new(&mdp, &policy);
                let _g = mdp.policy_costs(&policy);
                let matfree_setup = t0.elapsed().as_secs_f64();
                let matfree_resident = mdp.storage_bytes() + op.make_buffer().x().len() * 8;

                if matfree_setup >= assembled_setup {
                    // timing noise, not correctness — report, don't abort
                    eprintln!(
                        "WARNING: matfree setup {matfree_setup}s !< assembled \
                         {assembled_setup}s (noisy sample?)"
                    );
                }
                assert!(
                    matfree_resident < assembled_resident,
                    "matfree resident {matfree_resident}B !< assembled {assembled_resident}B"
                );
                (
                    assembled_setup,
                    matfree_setup,
                    assembled_resident,
                    matfree_resident,
                )
            });
            let (asm_setup, mf_setup, asm_bytes, mf_bytes) = out.swap_remove(0);
            vec![
                ("asm_setup_ms".to_string(), asm_setup * 1e3),
                ("mf_setup_ms".to_string(), mf_setup * 1e3),
                ("asm_MiB".to_string(), asm_bytes as f64 / (1 << 20) as f64),
                ("mf_MiB".to_string(), mf_bytes as f64 / (1 << 20) as f64),
            ]
        });
    }
    // (b) end-to-end solve cost under each backend (same solution, same
    // outer trajectory; the difference is setup work and ghost volume).
    for backend in [EvalBackend::MatFree, EvalBackend::Assembled] {
        let spec2 = Arc::clone(&eval_spec);
        suite.case(&format!("eval-backend/{}", backend.name()), move || {
            let spec3 = Arc::clone(&spec2);
            let opts = SolveOptions {
                method: Method::ipi_gmres(),
                eval_backend: backend,
                atol: 1e-8,
                max_outer: 100_000,
                ..Default::default()
            };
            let mut out = World::run(2, move |comm| {
                let mdp = spec3.build_dist(&comm, 0.99);
                let local = solve_dist(&comm, &mdp, &opts);
                gather_result(&comm, local)
            });
            let r = out.swap_remove(0);
            assert!(r.converged);
            vec![
                ("outer".to_string(), r.outer_iterations as f64),
                ("spmvs".to_string(), r.total_spmvs as f64),
                ("comm_MiB".to_string(), r.comm_bytes as f64 / (1 << 20) as f64),
            ]
        });
    }

    suite.finish();
}
