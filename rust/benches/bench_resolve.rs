//! E10 — warm-started incremental re-solve (DESIGN.md §16): cold vs warm
//! outer iterations and wall clock on drifting catalog models.
//!
//! Drift protocol per model: solve the base model cold (this is the
//! checkpoint), perturb a deterministic ~14% of the `(s, a)` cost entries
//! by up to ±2% (LCG-driven, seed-stable), then re-solve the drifted model
//! twice through the same `PreparedModel` — once cold, once seeded with the
//! pre-drift value vector. Both solves run to the *same* tolerance; the
//! warm one merely starts closer, so `iters_saved = cold_outer −
//! warm_outer` is the paper's incremental re-solve claim in one number.
//!
//! Reported metrics per case: `cold_outer`, `warm_outer`, `iters_saved`,
//! `cold_s`, `warm_s`, `speedup`. Merged into `BENCH_CI.json` by the
//! perf-smoke job with the same drop-out guard as the other suites.

use madupite::api::{model_from_options, MdpBuilder, Solver};
use madupite::util::args::Options;
use madupite::util::benchkit::Suite;
use std::time::Instant;

/// Deterministic ±2% multiplicative cost perturbation on every 7th state
/// (all actions): the drifted inputs are identical run over run, so the
/// iteration counts in BENCH_CI.json are comparable across commits.
fn cost_perturbation(name: &str, db: &Options) -> Vec<(usize, usize, f64)> {
    let generator = model_from_options(name, db).unwrap();
    let (n, m) = (generator.n_states(), generator.n_actions());
    let mut x: u64 = 0x9e3779b97f4a7c15;
    let mut patches = Vec::new();
    for s in (0..n).step_by(7) {
        for a in 0..m {
            x = x
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let u = (x >> 11) as f64 / (1u64 << 53) as f64; // uniform [0, 1)
            let factor = 1.0 + 0.02 * (2.0 * u - 1.0);
            patches.push((s, a, generator.cost(s, a) * factor));
        }
    }
    patches
}

fn main() {
    let mut suite = Suite::new("E10 warm resolve");

    // Two outer methods with very different iteration profiles: VI shows
    // the raw contraction distance, IPI shows the effect on a handful of
    // expensive outer steps.
    let models: &[(&str, &[&str])] = &[
        ("maze", &["-rows", "16", "-cols", "16"]),
        ("maintenance", &["-num_states", "400"]),
        ("replacement", &["-num_states", "400"]),
    ];
    for (name, params) in models {
        for method in ["vi", "ipi"] {
            let mut args = vec!["-model", name, "-method", method, "-atol", "1e-8"];
            args.extend_from_slice(params);
            let db = Options::parse(args.iter().map(|s| s.to_string()));
            let patches = cost_perturbation(name, &db);
            let builder = MdpBuilder::from_options(&db).unwrap();
            let solver = Solver::with_database(builder, db);

            // pre-drift checkpoint (the seed), outside the timed region
            let checkpoint = solver.solve().unwrap();

            suite.case(&format!("resolve/{name}/method={method}"), || {
                let mut prepared = solver.build().unwrap();
                prepared.patch_costs(&patches).unwrap();

                let t0 = Instant::now();
                let cold = solver.solve_prepared(&prepared).unwrap();
                let cold_s = t0.elapsed().as_secs_f64();

                prepared.warm_start(&checkpoint).unwrap();
                let t0 = Instant::now();
                let warm = solver.solve_prepared(&prepared).unwrap();
                let warm_s = t0.elapsed().as_secs_f64();

                // same model, same tolerance, both converged — the warm
                // solve only ever starts closer
                assert!(cold.result.converged && warm.result.converged);
                assert!(warm.result.outer_iterations <= cold.result.outer_iterations);
                let (co, wo) = (
                    cold.result.outer_iterations as f64,
                    warm.result.outer_iterations as f64,
                );
                vec![
                    ("cold_outer".to_string(), co),
                    ("warm_outer".to_string(), wo),
                    ("iters_saved".to_string(), co - wo),
                    ("cold_s".to_string(), cold_s),
                    ("warm_s".to_string(), warm_s),
                    ("speedup".to_string(), cold_s / warm_s.max(1e-12)),
                ]
            });
        }
    }

    suite.finish();
}
