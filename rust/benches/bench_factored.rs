//! E11 — ADD compression and structured-solve performance on factored
//! models (DESIGN.md §17).
//!
//! Two claims, one number each:
//!
//! - **Compression**: the hash-consed transition ADDs of `sis_factored`
//!   are at least 10× smaller than the nonzero count of the flat kernel
//!   they represent (`compression_x = flat_nnz / add_nodes`, asserted
//!   `>= 10` in-bench so a regression fails the perf smoke, not just
//!   drifts a number).
//! - **Solve**: structured value iteration vs. flat VI on the same spec
//!   at the same tolerance (`svi_s` / `flat_s`), with an in-bench
//!   agreement check so the timings can never come from diverging
//!   solutions.
//!
//! Reported metrics: `add_nodes`, `flat_nnz`, `compression_x` for the
//! compress case; `svi_s`, `flat_s`, `svi_iters`, `value_nodes` for the
//! solve cases. Merged into `BENCH_CI.json` by the perf-smoke job with
//! the same drop-out guard as the other suites.

use madupite::factored::{solve_svi, FactoredMdp, SviOptions};
use madupite::mdp::Objective;
use madupite::models::{factory::FactorySpec, sis_factored::SisFactoredSpec, ModelGenerator};
use madupite::solver::{solve_serial, Method, SolveOptions};
use madupite::util::benchkit::Suite;
use std::time::Instant;

fn main() {
    let mut suite = Suite::new("E11 factored ADD compression");

    // ---------------------------------------------------------- compress
    // sis_factored with 10 ring nodes: 1024 flat states whose kernel has
    // O(100k) nonzeros, against a few hundred shared ADD nodes.
    let sis10 = SisFactoredSpec::new(10).unwrap().factored_mdp().clone();
    suite.case("factored/sis_factored/compress", || {
        let flat_nnz = sis10.flat_nnz() as f64;
        // one backup is enough: the transition ADDs are built up front
        let probe = solve_svi(
            &sis10,
            0.95,
            Objective::Min,
            &SviOptions {
                max_iter: 1,
                ..Default::default()
            },
        )
        .unwrap();
        let add_nodes = probe.transition_nodes as f64;
        let compression_x = flat_nnz / add_nodes;
        assert!(
            compression_x >= 10.0,
            "ADD compression regressed below the 10x bar: \
             {add_nodes} transition nodes vs {flat_nnz} flat nonzeros"
        );
        vec![
            ("add_nodes".to_string(), add_nodes),
            ("flat_nnz".to_string(), flat_nnz),
            ("compression_x".to_string(), compression_x),
        ]
    });

    // ------------------------------------------------------------- solve
    let models: Vec<(&str, FactoredMdp)> = vec![
        (
            "sis_factored",
            SisFactoredSpec::new(8).unwrap().factored_mdp().clone(),
        ),
        ("factory", FactorySpec::new(4).unwrap().factored_mdp().clone()),
    ];
    for (name, fmdp) in models {
        // flat model built once, outside the timed region
        let mdp = fmdp.try_build_serial(0.95).unwrap();
        suite.case(&format!("factored/{name}/solve"), || {
            let t0 = Instant::now();
            let svi = solve_svi(
                &fmdp,
                0.95,
                Objective::Min,
                &SviOptions {
                    atol: 1e-8,
                    max_iter: 100_000,
                    ..Default::default()
                },
            )
            .unwrap();
            let svi_s = t0.elapsed().as_secs_f64();

            let t0 = Instant::now();
            let flat = solve_serial(
                &mdp,
                &SolveOptions {
                    method: Method::Vi,
                    atol: 1e-8,
                    max_outer: 100_000,
                    ..Default::default()
                },
            );
            let flat_s = t0.elapsed().as_secs_f64();

            // the timings are only meaningful if the answers agree
            assert!(svi.converged && flat.converged);
            let err = svi
                .value
                .iter()
                .zip(&flat.value)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            assert!(err < 1e-6, "{name}: svi/flat values diverged by {err:e}");

            vec![
                ("svi_s".to_string(), svi_s),
                ("flat_s".to_string(), flat_s),
                ("svi_iters".to_string(), svi.iterations as f64),
                ("value_nodes".to_string(), svi.value_nodes as f64),
            ]
        });
    }

    suite.finish();
}
