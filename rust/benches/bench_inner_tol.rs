//! E3 — Forcing-term sweep (DESIGN.md §6): how the inner tolerance
//! α (madupite's `-alpha`) trades outer iterations against inner SpMVs,
//! on the SIS epidemic instance with iPI(GMRES).
//!
//! Expected shape (iPI paper): total cost is U-shaped — very tight α wastes
//! inner iterations refining evaluations that the next policy switch
//! discards; very loose α degenerates toward VI's outer count. The optimum
//! sits in the broad middle, which is why madupite exposes the knob.

use madupite::models::{sis::SisSpec, ModelGenerator};
use madupite::solver::{solve_serial, Method, SolveOptions};
use madupite::util::benchkit::Suite;

fn main() {
    let mdp = SisSpec::standard(10_000, 4).build_serial(0.999);
    let mut suite = Suite::new("E3 forcing-term sweep");
    println!("workload: SIS population 10k, gamma=0.999, iPI(GMRES)");

    for alpha in [0.5, 1e-1, 1e-2, 1e-3, 1e-4, 1e-6, 1e-8] {
        let opts = SolveOptions {
            method: Method::ipi_gmres(),
            atol: 1e-8,
            alpha,
            max_outer: 500_000,
            ..Default::default()
        };
        suite.case(&format!("alpha={alpha:.0e}"), || {
            let r = solve_serial(&mdp, &opts);
            assert!(r.converged);
            vec![
                ("outer".to_string(), r.outer_iterations as f64),
                ("inner".to_string(), r.total_inner_iterations as f64),
                ("spmvs".to_string(), r.total_spmvs as f64),
            ]
        });
    }
    suite.finish();
}
