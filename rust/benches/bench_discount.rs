//! E4 — Discount sweep (DESIGN.md §6): γ ∈ {0.9, 0.99, 0.999, 0.9999} on a
//! fixed Garnet MDP; VI/mPI versus iPI(GMRES) and iPI(BiCGStab).
//!
//! Expected shape (claim C2, the headline of the iPI papers): fixed-point
//! methods need Θ(1/(1−γ)) sweeps, so their SpMV count explodes as γ → 1,
//! while Krylov-based iPI grows far more slowly — "poor performance for a
//! significant class of problems" is this column.

use madupite::models::{garnet::GarnetSpec, ModelGenerator};
use madupite::solver::{solve_serial, Method, SolveOptions};
use madupite::util::benchkit::Suite;

fn main() {
    let spec = GarnetSpec::new(10_000, 4, 5, 5);
    let mut suite = Suite::new("E4 discount sweep");
    println!("workload: Garnet n=10k b=5; tolerance 1e-6");

    for gamma in [0.9, 0.99, 0.999, 0.9999] {
        let mdp = spec.build_serial(gamma);
        for method in [
            Method::Vi,
            Method::Mpi { sweeps: 20 },
            Method::ipi_gmres(),
            Method::ipi_bicgstab(),
        ] {
            let opts = SolveOptions {
                method: method.clone(),
                atol: 1e-6,
                max_outer: 2_000_000,
                ..Default::default()
            };
            suite.case(&format!("gamma={gamma}/{}", method.name()), || {
                let r = solve_serial(&mdp, &opts);
                assert!(r.converged, "gamma={gamma} {}", method.name());
                vec![
                    ("outer".to_string(), r.outer_iterations as f64),
                    ("spmvs".to_string(), r.total_spmvs as f64),
                ]
            });
        }
    }
    suite.finish();
}
