//! E5 — Toolbox comparison (DESIGN.md §6, claim C4): madupite-rs vs the
//! two comparators the paper names, on a size sweep:
//!
//! - `mdpsolver-like`  — nested `std::vector` storage + modified PI only
//! - `pymdp-like`      — dense (A,S,S) tensors + plain VI (only run at
//!                       small n: its memory is Θ(A·n²) by construction,
//!                       which *is* the finding)
//!
//! Reported: wall time to the same solution quality + transition-storage
//! bytes. Expected shape: madupite's CSR path wins on time as n grows, and
//! the memory column shows why pymdptoolbox cannot scale at all and why
//! mdpsolver's nested vectors waste bytes per nonzero.

use madupite::baseline::{mdpsolver_like::NestedVecMdp, pymdp_like::DenseMdp};
use madupite::models::{garnet::GarnetSpec, gridworld::GridSpec, ModelGenerator};
use madupite::solver::{solve_serial, Method, SolveOptions};
use madupite::util::benchkit::Suite;

fn main() {
    let mut suite = Suite::new("E5 toolbox comparison");

    // size sweep over Garnet (b = 5, m = 4, γ = 0.99)
    for n in [1_000usize, 10_000, 50_000] {
        let mdp = GarnetSpec::new(n, 4, 5, 3).build_serial(0.99);

        // label carries the Method::name() so tables line up with E1/E4
        let method = Method::ipi_gmres();
        suite.case(&format!("garnet{n}/madupite-{}", method.name()), || {
            let r = solve_serial(
                &mdp,
                &SolveOptions {
                    method: method.clone(),
                    atol: 1e-8,
                    ..Default::default()
                },
            );
            assert!(r.converged);
            vec![
                ("spmvs".to_string(), r.total_spmvs as f64),
                (
                    "storage_MiB".to_string(),
                    mdp.storage_bytes() as f64 / (1 << 20) as f64,
                ),
            ]
        });

        let nested = NestedVecMdp::from_mdp(&mdp);
        suite.case(&format!("garnet{n}/mdpsolver-like"), || {
            let r = nested.solve_mpi(1e-8, 20, 1_000_000);
            assert!(r.converged);
            vec![
                ("iters".to_string(), r.iterations as f64),
                (
                    "storage_MiB".to_string(),
                    r.storage_bytes as f64 / (1 << 20) as f64,
                ),
            ]
        });

        // dense VI only feasible at small n: Θ(A·n²) memory
        if n <= 1_000 {
            let dense = DenseMdp::from_mdp(&mdp);
            suite.case(&format!("garnet{n}/pymdp-like"), || {
                let r = dense.solve_vi(1e-6, 1_000_000);
                assert!(r.converged);
                vec![
                    ("iters".to_string(), r.iterations as f64),
                    (
                        "storage_MiB".to_string(),
                        r.storage_bytes as f64 / (1 << 20) as f64,
                    ),
                ]
            });
        } else {
            println!(
                "garnet{n}/pymdp-like skipped: dense storage would need {:.1} GiB",
                (4usize * n * n * 8) as f64 / (1u64 << 30) as f64
            );
        }
    }

    // one structured workload: maze 100×100. Mazes are wavefront-limited
    // (outer count ≈ maze diameter regardless of evaluation accuracy), so
    // the *tailored* iPI configuration uses a loose forcing term — this is
    // claim C2 in action: one knob, not a different solver.
    let maze = GridSpec::maze(100, 100, 21).build_serial(0.99);
    let method = Method::ipi_gmres();
    suite.case(&format!("maze100/madupite-{}", method.name()), || {
        let r = solve_serial(
            &maze,
            &SolveOptions {
                method: method.clone(),
                atol: 1e-8,
                alpha: 1e-2,
                max_outer: 100_000,
                ..Default::default()
            },
        );
        assert!(r.converged);
        vec![("spmvs".to_string(), r.total_spmvs as f64)]
    });
    let nested = NestedVecMdp::from_mdp(&maze);
    suite.case("maze100/mdpsolver-like", || {
        let r = nested.solve_mpi(1e-8, 20, 1_000_000);
        assert!(r.converged);
        vec![("iters".to_string(), r.iterations as f64)]
    });

    suite.finish();
}
