//! E2 — Strong scaling (DESIGN.md §6): solve a fixed large maze on a
//! hybrid `ranks × threads` grid — worlds of 1/2/4/8 simulated ranks, each
//! rank running its kernels on 1 or more intra-rank worker threads
//! (`util::par`, DESIGN.md §11). On this single-CPU container the
//! meaningful rank-scaling observables are **communication volume**,
//! message counts and per-rank byte balance; the thread dimension is the
//! one that actually buys wall time on a multi-core box (wall time is
//! reported for completeness — ranks share cores, see DESIGN.md §3).
//!
//! Expected shape (claim C3): per-rank memory and compute shrink ~1/R;
//! total comm volume grows sub-linearly (ghost boundary + reductions), the
//! per-rank balance stays near 1, and — thread-count independence — every
//! `ranks=R` row reports the identical outer/spmv counts for every `t`.
//!
//! The grid also carries a **comm-overlap** dimension (DESIGN.md §14):
//! every `(ranks, t)` point runs with `-comm_overlap off` and `on`.
//! Overlap must leave every result/counter column bitwise identical —
//! including `comm_bytes`, since the split-phase exchange moves the same
//! ghost f64s — and only `comm_time_us` / wall time may move. The
//! solve-only `comm_bytes` and per-outer-iteration `comm_KiB_per_iter`
//! columns make the ghost-subset exchange win (policy matrices fetch only
//! the ghost entries the selected policy references) visible per
//! iteration; CI's perf-smoke job fails if these fields drop out of
//! `BENCH_CI.json`.
//!
//! Environment knobs: `MADUPITE_SCALING_ROWS` (maze side, default 512) and
//! `MADUPITE_BENCH_THREADS` (comma-separated thread counts, default 1,2).

use madupite::comm::{overlap, OverlapMode, World};
use madupite::models::{gridworld::GridSpec, ModelGenerator};
use madupite::solver::{gather_result, solve_dist, Method, SolveOptions};
use madupite::util::benchkit::{thread_counts, Suite};
use madupite::util::par;
use std::sync::Arc;

fn main() {
    let rows: usize = std::env::var("MADUPITE_SCALING_ROWS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(512);
    let threads = thread_counts(&[1, 2]);
    let spec = Arc::new(GridSpec::maze(rows, rows, 2024));
    let n = rows * rows;
    let mut suite = Suite::new("E2 strong scaling");
    println!("workload: {rows}x{rows} maze = {n} states, iPI(GMRES), gamma=0.9");

    for ranks in [1usize, 2, 4, 8] {
        for &t in &threads {
            for ov in [OverlapMode::Off, OverlapMode::On] {
                par::set_threads(t);
                let spec2 = Arc::clone(&spec);
                let name = format!("ranks={ranks}/t={t}/overlap={}", ov.name());
                suite.case(&name, move || {
                    overlap::set_mode(ov);
                    let spec3 = Arc::clone(&spec2);
                    let opts = SolveOptions {
                        method: Method::ipi_gmres(),
                        atol: 1e-8,
                        alpha: 1e-2,
                        max_outer: 100_000,
                        ..Default::default()
                    };
                    let mut out = World::run(ranks, move |comm| {
                        let mdp = spec3.build_dist(&comm, 0.9);
                        let local_bytes = mdp.storage_bytes();
                        let local = solve_dist(&comm, &mdp, &opts);
                        let snap = comm.stats().snapshot();
                        let r = gather_result(&comm, local);
                        (r, snap, local_bytes)
                    });
                    let (r, snap, local_bytes) = out.swap_remove(0);
                    assert!(r.converged);
                    vec![
                        ("cores".to_string(), (r.ranks * r.threads) as f64),
                        ("outer".to_string(), r.outer_iterations as f64),
                        ("spmvs".to_string(), r.total_spmvs as f64),
                        // Solve-only comm accounting from SolveResult (the
                        // snapshot also counts the model build).
                        ("comm_bytes".to_string(), r.comm_bytes as f64),
                        ("comm_time_us".to_string(), r.comm_time_us as f64),
                        (
                            "comm_KiB_per_iter".to_string(),
                            r.comm_bytes as f64
                                / (1 << 10) as f64
                                / r.outer_iterations.max(1) as f64,
                        ),
                        (
                            "comm_MiB".to_string(),
                            snap.total_bytes() as f64 / (1 << 20) as f64,
                        ),
                        ("msgs".to_string(), snap.total_msgs() as f64),
                        (
                            "balance".to_string(),
                            if ranks > 1 { snap.imbalance() } else { 1.0 },
                        ),
                        (
                            "rank0_MiB".to_string(),
                            local_bytes as f64 / (1 << 20) as f64,
                        ),
                    ]
                });
            }
        }
    }
    overlap::set_mode(OverlapMode::Auto);
    par::set_threads(1);
    suite.finish();
}
