//! E2 — Strong scaling (DESIGN.md §6): solve a fixed large maze on
//! worlds of 1/2/4/8 simulated ranks. On this single-CPU container the
//! meaningful scaling observables are **communication volume**, message
//! counts and per-rank byte balance (wall time is reported for
//! completeness but ranks share one core — see DESIGN.md §3).
//!
//! Expected shape (claim C3): per-rank memory and compute shrink ~1/R;
//! total comm volume grows sub-linearly (ghost boundary + reductions),
//! and the per-rank balance stays near 1.

use madupite::comm::World;
use madupite::models::{gridworld::GridSpec, ModelGenerator};
use madupite::solver::{gather_result, solve_dist, Method, SolveOptions};
use madupite::util::benchkit::Suite;
use std::sync::Arc;

fn main() {
    let rows: usize = std::env::var("MADUPITE_SCALING_ROWS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(512);
    let spec = Arc::new(GridSpec::maze(rows, rows, 2024));
    let n = rows * rows;
    let mut suite = Suite::new("E2 strong scaling");
    println!("workload: {rows}x{rows} maze = {n} states, iPI(GMRES), gamma=0.9");

    for ranks in [1usize, 2, 4, 8] {
        let spec2 = Arc::clone(&spec);
        suite.case(&format!("ranks={ranks}"), move || {
            let spec3 = Arc::clone(&spec2);
            let opts = SolveOptions {
                method: Method::ipi_gmres(),
                atol: 1e-8,
                alpha: 1e-2,
                max_outer: 100_000,
                ..Default::default()
            };
            let mut out = World::run(ranks, move |comm| {
                let mdp = spec3.build_dist(&comm, 0.9);
                let local_bytes = mdp.storage_bytes();
                let local = solve_dist(&comm, &mdp, &opts);
                let snap = comm.stats().snapshot();
                let r = gather_result(&comm, local);
                (r, snap, local_bytes)
            });
            let (r, snap, local_bytes) = out.swap_remove(0);
            assert!(r.converged);
            vec![
                ("outer".to_string(), r.outer_iterations as f64),
                ("spmvs".to_string(), r.total_spmvs as f64),
                (
                    "comm_MiB".to_string(),
                    snap.total_bytes() as f64 / (1 << 20) as f64,
                ),
                ("msgs".to_string(), snap.total_msgs() as f64),
                (
                    "balance".to_string(),
                    if ranks > 1 { snap.imbalance() } else { 1.0 },
                ),
                (
                    "rank0_MiB".to_string(),
                    local_bytes as f64 / (1 << 20) as f64,
                ),
            ]
        });
    }
    suite.finish();
}
