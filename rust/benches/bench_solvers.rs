//! E1 — Method comparison (DESIGN.md §6): VI vs mPI vs iPI(GMRES /
//! BiCGStab / TFQMR) across the three benchmark families of the iPI
//! companion paper. Reports outer iterations, total SpMVs (the papers'
//! hardware-independent cost unit) and wall time to a fixed tolerance.
//!
//! Expected shape (paper claims C1/C2): the Krylov iPI variants dominate
//! mPI/VI in SpMV count, most dramatically on the high-γ Garnet instance.

use madupite::models::{garnet::GarnetSpec, gridworld::GridSpec, sis::SisSpec, ModelGenerator};
use madupite::solver::{solve_serial, Method, SolveOptions};
use madupite::util::benchkit::Suite;

fn run_case(suite: &mut Suite, label: &str, mdp: &madupite::mdp::Mdp, method: Method) {
    let opts = SolveOptions {
        method: method.clone(),
        atol: 1e-8,
        max_outer: 500_000,
        ..Default::default()
    };
    suite.case(&format!("{label}/{}", method.name()), || {
        let r = solve_serial(mdp, &opts);
        assert!(r.converged, "{label}/{} did not converge", method.name());
        vec![
            ("outer".to_string(), r.outer_iterations as f64),
            ("spmvs".to_string(), r.total_spmvs as f64),
            ("residual".to_string(), r.residual),
        ]
    });
}

fn main() {
    let mut suite = Suite::new("E1 method comparison");
    let methods = || {
        vec![
            Method::Vi,
            Method::Mpi { sweeps: 5 },
            Method::Mpi { sweeps: 20 },
            Method::ipi_gmres(),
            Method::ipi_bicgstab(),
            Method::ipi_tfqmr(),
        ]
    };

    // maze 200×200, γ = 0.99 — navigation family
    let maze = GridSpec::maze(200, 200, 11).build_serial(0.99);
    for m in methods() {
        run_case(&mut suite, "maze200", &maze, m);
    }

    // SIS population 10k, γ = 0.95 — epidemic family
    let sis = SisSpec::standard(10_000, 4).build_serial(0.95);
    for m in methods() {
        run_case(&mut suite, "sis10k", &sis, m);
    }

    // Garnet n = 20k, b = 5, γ = 0.999 — the hard high-discount family
    let garnet = GarnetSpec::new(20_000, 4, 5, 13).build_serial(0.999);
    for m in methods() {
        run_case(&mut suite, "garnet20k", &garnet, m);
    }

    suite.finish();
}
