//! E8 — offline data pipeline throughput (DESIGN.md §6): the `.mdpb` v2
//! read/write paths that feed every out-of-core workload.
//!
//! - **generate_stream**: `ModelGenerator::write_mdpb` — two generator
//!   passes + chunked seek-writes, O(chunk) memory, at several world
//!   sizes (bytes are identical for all of them by construction).
//! - **save_serial**: in-memory `Mdp` → file through the same writer.
//! - **load_serial** vs **load_dist**: full read vs rank-sliced partial
//!   reads + ghost-plan assembly at several world sizes.
//!
//! Reported metric: effective MiB/s against the file size, the number the
//! "solve MDPs whose data was collected offline" claim (C5) rests on.

use madupite::comm::World;
use madupite::mdp::{io, Objective};
use madupite::models::{garnet::GarnetSpec, ModelGenerator};
use madupite::util::benchkit::Suite;
use std::sync::Arc;

fn tmpfile(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("madupite-bench-io");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn main() {
    let mut suite = Suite::new("E8 io pipeline");
    let (n, m, b) = (100_000usize, 4usize, 5usize);
    let gamma = 0.99;
    let spec = Arc::new(GarnetSpec::new(n, m, b, 17));

    // reference file + size (also the load workload below)
    let ref_path = tmpfile("e8_ref.mdpb");
    let mdp = spec.build_serial(gamma);
    io::save(&mdp, &ref_path).unwrap();
    let file_bytes = std::fs::metadata(&ref_path).unwrap().len() as f64;
    let mib = file_bytes / (1u64 << 20) as f64;
    println!(
        "workload: garnet n={n} m={m} branching={b} → {:.1} MiB on disk",
        mib
    );

    // --- streaming generation at several world sizes -----------------------
    for ranks in [1usize, 2, 4] {
        let spec2 = Arc::clone(&spec);
        let path = tmpfile(&format!("e8_gen_r{ranks}.mdpb"));
        suite.case(&format!("generate_stream/ranks={ranks}"), move || {
            let spec3 = Arc::clone(&spec2);
            let p = path.clone();
            let results = World::run(ranks, move |comm| {
                spec3
                    .write_mdpb(&comm, gamma, Objective::Min, &p, io::DEFAULT_CHUNK_ROWS)
                    .unwrap()
            });
            let nnz = results[0].nnz;
            let bytes = std::fs::metadata(&path).unwrap().len() as f64;
            vec![
                ("file_MiB".to_string(), bytes / (1u64 << 20) as f64),
                ("nnz".to_string(), nnz as f64),
            ]
        });
    }

    // --- in-memory save (the serial writer over an assembled Mdp) ----------
    {
        let path = tmpfile("e8_save.mdpb");
        let mdp2 = mdp.clone();
        suite.case("save_serial", move || {
            io::save(&mdp2, &path).unwrap();
            vec![("file_MiB".to_string(), mib)]
        });
    }

    // --- serial load --------------------------------------------------------
    {
        let path = ref_path.clone();
        suite.case("load_serial", move || {
            let loaded = io::load(&path).unwrap();
            vec![
                ("file_MiB".to_string(), mib),
                ("nnz".to_string(), loaded.transitions().nnz() as f64),
            ]
        });
    }

    // --- rank-sliced distributed load --------------------------------------
    for ranks in [1usize, 2, 4] {
        let path = ref_path.clone();
        suite.case(&format!("load_dist/ranks={ranks}"), move || {
            let p = path.clone();
            let storage: usize = World::run(ranks, move |comm| {
                let d = io::load_dist(&comm, &p).unwrap();
                d.storage_bytes()
            })
            .into_iter()
            .sum();
            vec![
                ("file_MiB".to_string(), mib),
                (
                    "storage_MiB".to_string(),
                    storage as f64 / (1u64 << 20) as f64,
                ),
            ]
        });
    }

    suite.finish();
}
