//! E6 — Kernel microbenchmarks (DESIGN.md §6): the building blocks under
//! the solver, plus the L1/L2 PJRT dense path vs the native Rust kernel.
//!
//! - CSR SpMV at several sizes → effective GB/s against the memory-traffic
//!   roofline estimate (8B value + 8B col index per nnz + x/y traffic).
//! - Stacked Bellman backup (the per-outer-iteration unit).
//! - PJRT artifact execution (Pallas kernel via HLO) vs native dense Rust:
//!   dispatch overhead + crossover block size, and artifact compile time.

use madupite::models::{garnet::GarnetSpec, ModelGenerator};
use madupite::runtime::{bellman_dense_native, random_block, DenseBellman, Engine};
use madupite::util::benchkit::{fmt_time, Suite};
use std::time::Instant;

/// Random sparse MDP workload (Garnet) — deterministic in seed.
fn random_mdp_bench(seed: u64, n: usize, m: usize, gamma: f64, b: usize) -> madupite::mdp::Mdp {
    GarnetSpec::new(n, m, b, seed).build_serial(gamma)
}

fn main() {
    let mut suite = Suite::new("E6 kernels");

    // --- CSR SpMV roofline -------------------------------------------------
    for n in [10_000usize, 100_000, 1_000_000] {
        let mdp = random_mdp_bench(7, n, 4, 0.99, 5);
        let t = mdp.transitions();
        let x = vec![1.0f64; n];
        let mut y = vec![0.0f64; t.nrows()];
        let nnz = t.nnz();
        suite.case(&format!("spmv/n={n}"), || {
            t.spmv(&x, &mut y);
            let bytes = (nnz * 16 + (t.nrows() + n) * 8) as f64;
            vec![
                ("nnz".to_string(), nnz as f64),
                ("traffic_MiB".to_string(), bytes / (1 << 20) as f64),
            ]
        });
    }

    // --- full Bellman backup (serial world) --------------------------------
    for n in [100_000usize, 1_000_000] {
        let mdp = random_mdp_bench(9, n, 4, 0.99, 5);
        suite.case(&format!("bellman_backup/n={n}"), || {
            let v = vec![0.0f64; n];
            let (tv, _) = mdp.bellman(&v);
            vec![("checksum".to_string(), tv[0])]
        });
    }

    // --- PJRT dense path vs native rust ------------------------------------
    match Engine::load("artifacts") {
        Err(e) => println!("PJRT cases skipped: {e}"),
        Ok(mut engine) => {
            for (n, m) in [(64usize, 4usize), (128, 4), (256, 8)] {
                let t0 = Instant::now();
                let db = DenseBellman::new(&engine, n, m).unwrap();
                let (p, g, v) = random_block(3, n, m);
                // force compile before timing execution
                let _ = db.bellman(&mut engine, &p, &g, &v, 0.95).unwrap();
                let compile = t0.elapsed().as_secs_f64();
                println!("pjrt {n}x{m}: first-call (compile+exec) {}", fmt_time(compile));

                suite.case(&format!("pjrt_bellman/{n}x{m}"), || {
                    let (tv, _) = db.bellman(&mut engine, &p, &g, &v, 0.95).unwrap();
                    vec![("checksum".to_string(), tv[0] as f64)]
                });
                suite.case(&format!("native_bellman/{n}x{m}"), || {
                    let (tv, _) = bellman_dense_native(n, m, &p, &g, &v, 0.95);
                    vec![("checksum".to_string(), tv[0] as f64)]
                });
                suite.case(&format!("pjrt_vi10/{n}x{m}"), || {
                    let out = db.vi_sweeps(&mut engine, &p, &g, &v, 0.95).unwrap();
                    vec![("checksum".to_string(), out[0] as f64)]
                });
            }
        }
    }

    suite.finish();
}
