//! E6 — Kernel microbenchmarks (DESIGN.md §6): the building blocks under
//! the solver, plus the L1/L2 PJRT dense path vs the native Rust kernel.
//!
//! - CSR SpMV at several sizes → effective GB/s against the memory-traffic
//!   roofline estimate (8B value + 8B col index per nnz + x/y traffic).
//! - Stacked Bellman backup (the per-outer-iteration unit).
//! - Both of the above across an intra-rank **thread dimension**
//!   (`util::par`, DESIGN.md §11): `t=1` is the serial baseline, higher
//!   `t` must show near-linear speedup on a multi-core box while staying
//!   bitwise identical (asserted via checksums).
//! - Policy operator `I − γ P_π`: fused matrix-free application off the
//!   stacked kernel vs assembly + apply of an explicit `P_π` CSR — the
//!   per-policy-change setup cost and memory the `MatFree` backend removes.
//! - Kernel-backend ablation (DESIGN.md §13): the same SpMV and Bellman
//!   backup with the SIMD lane kernels forced off (`scalar`) vs on
//!   (`simd`) — the per-backend entries the CI perf-smoke publishes.
//! - Eval-backend ablation on a banded model: fused matrix-free vs the
//!   lane-blocked `bsr` copy vs the compressed `f32` operator, per apply.
//! - PJRT artifact execution (Pallas kernel via HLO) vs native dense Rust:
//!   dispatch overhead + crossover block size, and artifact compile time.
//!
//! Environment knobs: `MADUPITE_BENCH_THREADS` (comma-separated thread
//! counts, default `1,2,4`) and `MADUPITE_BENCH_MAX_N` (skip workloads
//! larger than this state count — CI's perf-smoke uses it to bound wall
//! time), on top of benchkit's `MADUPITE_BENCH_SAMPLES`/`_BUDGET_MS`.

use madupite::comm::World;
use madupite::ksp::{Apply, LinOp};
use madupite::linalg::Csr;
use madupite::mdp::{
    BsrPolicyOp, Discount, DiscountMode, DistMdp, F32PolicyOp, MatFreePolicyOp, Mdp,
};
use madupite::models::{garnet::GarnetSpec, ModelGenerator};
use madupite::runtime::{bellman_dense_native, random_block, DenseBellman, Engine};
use madupite::util::benchkit::{fmt_time, thread_counts, Suite};
use madupite::util::par;
use madupite::util::simd::{self, KernelBackend};
use std::sync::Arc;
use std::time::Instant;

/// Random sparse MDP workload (Garnet) — deterministic in seed.
fn random_mdp_bench(seed: u64, n: usize, m: usize, gamma: f64, b: usize) -> madupite::mdp::Mdp {
    GarnetSpec::new(n, m, b, seed).build_serial(gamma)
}

/// Bit-exact checksum of a whole vector (rotate-xor of every element's
/// bits), so the determinism gate catches divergence in *any* chunk, not
/// just the first element.
fn bits_checksum(xs: &[f64]) -> u64 {
    xs.iter()
        .fold(0u64, |acc, v| acc.rotate_left(1) ^ v.to_bits())
}

/// Workload size cap (`MADUPITE_BENCH_MAX_N`) for time-bounded CI runs.
fn max_n() -> usize {
    std::env::var("MADUPITE_BENCH_MAX_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(usize::MAX)
}

fn main() {
    let mut suite = Suite::new("E6 kernels");
    let threads = thread_counts(&[1, 2, 4]);
    let max_n = max_n();

    // --- CSR SpMV roofline, threads × size ---------------------------------
    for n in [10_000usize, 100_000, 1_000_000] {
        if n > max_n {
            println!("spmv/n={n}: skipped (MADUPITE_BENCH_MAX_N={max_n})");
            continue;
        }
        let mdp = random_mdp_bench(7, n, 4, 0.99, 5);
        let t = mdp.transitions();
        let x = vec![1.0f64; n];
        let mut y = vec![0.0f64; t.nrows()];
        let nnz = t.nnz();
        let mut checksum_t1: Option<u64> = None;
        for &nt in &threads {
            par::set_threads(nt);
            suite.case(&format!("spmv/n={n}/t={nt}"), || {
                t.spmv(&x, &mut y);
                let bytes = (nnz * 16 + (t.nrows() + n) * 8) as f64;
                vec![
                    ("threads".to_string(), nt as f64),
                    ("nnz".to_string(), nnz as f64),
                    ("traffic_MiB".to_string(), bytes / (1 << 20) as f64),
                ]
            });
            // determinism gate: identical bits (whole vector) at every
            // thread count
            let bits = bits_checksum(&y);
            match checksum_t1 {
                None => checksum_t1 = Some(bits),
                Some(b) => assert_eq!(b, bits, "spmv not thread-count independent"),
            }
        }
    }

    // --- full Bellman backup (serial world), threads × size ----------------
    for n in [100_000usize, 1_000_000] {
        if n > max_n {
            println!("bellman_backup/n={n}: skipped (MADUPITE_BENCH_MAX_N={max_n})");
            continue;
        }
        let mdp = random_mdp_bench(9, n, 4, 0.99, 5);
        let mut checksum_t1: Option<u64> = None;
        for &nt in &threads {
            par::set_threads(nt);
            let mut last = 0u64;
            suite.case(&format!("bellman_backup/n={n}/t={nt}"), || {
                let v = vec![0.0f64; n];
                let (tv, _) = mdp.bellman(&v);
                last = bits_checksum(&tv);
                vec![
                    ("threads".to_string(), nt as f64),
                    ("checksum".to_string(), tv[0]),
                ]
            });
            match checksum_t1 {
                None => checksum_t1 = Some(last),
                Some(b) => assert_eq!(b, last, "bellman not thread-count independent"),
            }
        }
    }
    par::set_threads(1);

    // --- kernel-backend ablation: SIMD lanes forced off vs on --------------
    // Same workload, process-global kernel switch (DESIGN.md §13.1). These
    // are the per-backend entries CI's perf-smoke merges into BENCH_CI.json.
    for n in [100_000usize] {
        if n > max_n {
            println!("kernels/n={n}: skipped (MADUPITE_BENCH_MAX_N={max_n})");
            continue;
        }
        let mdp = random_mdp_bench(7, n, 4, 0.99, 5);
        let t = mdp.transitions();
        let x = vec![1.0f64; n];
        let mut y = vec![0.0f64; t.nrows()];
        let nnz = t.nnz();
        for backend in [KernelBackend::Scalar, KernelBackend::Simd] {
            simd::set_kernel_backend(backend);
            suite.case(&format!("spmv_kernels/n={n}/k={}", backend.name()), || {
                t.spmv(&x, &mut y);
                vec![("nnz".to_string(), nnz as f64)]
            });
            suite.case(
                &format!("bellman_kernels/n={n}/k={}", backend.name()),
                || {
                    let v = vec![0.0f64; n];
                    let (tv, _) = mdp.bellman(&v);
                    vec![("checksum".to_string(), tv[0])]
                },
            );
        }
        simd::set_kernel_backend(KernelBackend::Simd);
    }

    // --- eval-backend ablation: matfree vs bsr vs f32 per apply ------------
    // Banded transitions (successors s, s+1, s+2): the clustered-column
    // structure the 1×LANES blocks are built for, so the `bsr` heuristic
    // keeps its packed copy instead of falling back.
    for n in [100_000usize] {
        if n > max_n {
            println!("policy_op_backends/n={n}: skipped (MADUPITE_BENCH_MAX_N={max_n})");
            continue;
        }
        let m = 4usize;
        let mut trips = Vec::with_capacity(n * m * 3);
        for s in 0..n {
            for a in 0..m {
                let r = s * m + a;
                trips.push((r, s, 0.5));
                trips.push((r, (s + 1) % n, 0.3));
                trips.push((r, (s + 2) % n, 0.2));
            }
        }
        let trans = Csr::from_triplets(n * m, n, &trips);
        let costs: Vec<f64> = (0..n * m).map(|i| (i % 17) as f64 * 0.1).collect();
        let mdp = Arc::new(Mdp::new(n, m, trans, costs, 0.99).unwrap());
        suite.case(&format!("policy_op_backends/n={n}"), move || {
            let mdp2 = Arc::clone(&mdp);
            let mut out = World::run(1, move |comm| {
                let d = DistMdp::from_serial(&comm, &mdp2);
                let nl = d.local_states();
                let policy: Vec<usize> = (0..nl).map(|s| s % d.n_actions()).collect();
                let x: Vec<f64> = (0..nl).map(|i| (i as f64 * 0.01).sin()).collect();
                let mut y = vec![0.0; nl];

                let mf = MatFreePolicyOp::new(&d, &policy);
                let mut buf = mf.make_buffer();
                let t0 = Instant::now();
                for _ in 0..10 {
                    mf.apply(&comm, &x, &mut y, &mut buf);
                }
                let mf_apply = t0.elapsed().as_secs_f64() / 10.0;
                let y_mf = y.clone();

                let bsr = BsrPolicyOp::new(&d, &policy);
                assert!(bsr.uses_blocks(), "banded rows must pass the fill heuristic");
                let mut buf = bsr.make_buffer();
                let t0 = Instant::now();
                for _ in 0..10 {
                    bsr.apply(&comm, &x, &mut y, &mut buf);
                }
                let bsr_apply = t0.elapsed().as_secs_f64() / 10.0;
                let max_diff = y
                    .iter()
                    .zip(&y_mf)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f64, f64::max);
                assert!(max_diff < 1e-12, "bsr apply diverged: max|Δ| = {max_diff}");

                let f32op = F32PolicyOp::new(&d, &policy);
                let mut buf = f32op.make_buffer();
                let t0 = Instant::now();
                for _ in 0..10 {
                    f32op.apply(&comm, &x, &mut y, &mut buf);
                }
                let f32_apply = t0.elapsed().as_secs_f64() / 10.0;
                let max_diff = y
                    .iter()
                    .zip(&y_mf)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f64, f64::max);
                assert!(max_diff < 1e-5, "f32 apply off its envelope: max|Δ| = {max_diff}");

                (mf_apply, bsr_apply, f32_apply, f32op.storage_bytes())
            });
            let (mf_apply, bsr_apply, f32_apply, f32_bytes) = out.swap_remove(0);
            vec![
                ("mf_apply_ms".to_string(), mf_apply * 1e3),
                ("bsr_apply_ms".to_string(), bsr_apply * 1e3),
                ("f32_apply_ms".to_string(), f32_apply * 1e3),
                ("f32_MiB".to_string(), f32_bytes as f64 / (1 << 20) as f64),
            ]
        });
    }

    // --- policy operator: fused matrix-free vs assembled P_π ---------------
    // Setup = what a policy change costs before the first inner iteration;
    // apply = steady-state per-iteration cost of y ← (I − γ P_π) x.
    for n in [100_000usize] {
        if n > max_n {
            println!("policy_op/n={n}: skipped (MADUPITE_BENCH_MAX_N={max_n})");
            continue;
        }
        let mdp = Arc::new(random_mdp_bench(21, n, 4, 0.99, 5));
        for &nt in &threads {
            par::set_threads(nt);
            let mdp2 = Arc::clone(&mdp);
            suite.case(&format!("policy_op/n={n}/t={nt}"), move || {
                let mdp3 = Arc::clone(&mdp2);
                let mut out = World::run(1, move |comm| {
                    let d = DistMdp::from_serial(&comm, &mdp3);
                    let nl = d.local_states();
                    let policy: Vec<usize> = (0..nl).map(|s| s % d.n_actions()).collect();
                    let x: Vec<f64> = (0..nl).map(|i| (i as f64 * 0.01).sin()).collect();
                    let mut y = vec![0.0; nl];

                    // assembled: ghost plan + CSR copy, then apply
                    let t0 = Instant::now();
                    let (p_pi, _g) = d.policy_system(&comm, &policy);
                    let assembled_setup = t0.elapsed().as_secs_f64();
                    let asm = LinOp::new(&p_pi, d.gamma());
                    let mut buf = asm.make_buffer();
                    let t0 = Instant::now();
                    for _ in 0..10 {
                        asm.apply(&comm, &x, &mut y, &mut buf);
                    }
                    let assembled_apply = t0.elapsed().as_secs_f64() / 10.0;
                    let assembled_bytes = p_pi.local().storage_bytes();
                    let y_assembled = y.clone();

                    // matrix-free: O(1) setup, apply off the stacked kernel
                    let t0 = Instant::now();
                    let mf = MatFreePolicyOp::new(&d, &policy);
                    let _g = d.policy_costs(&policy);
                    let matfree_setup = t0.elapsed().as_secs_f64();
                    let mut buf = mf.make_buffer();
                    let t0 = Instant::now();
                    for _ in 0..10 {
                        mf.apply(&comm, &x, &mut y, &mut buf);
                    }
                    let matfree_apply = t0.elapsed().as_secs_f64() / 10.0;
                    let max_diff = y
                        .iter()
                        .zip(&y_assembled)
                        .map(|(a, b)| (a - b).abs())
                        .fold(0.0f64, f64::max);
                    assert!(
                        max_diff < 1e-12,
                        "matfree and assembled applies diverged: max|Δ| = {max_diff}"
                    );
                    if matfree_setup >= assembled_setup {
                        // timing noise, not correctness — report, don't abort
                        eprintln!(
                            "WARNING: matrix-free setup {matfree_setup}s not below \
                             assembled {assembled_setup}s (noisy sample?)"
                        );
                    }
                    (
                        assembled_setup,
                        matfree_setup,
                        assembled_apply,
                        matfree_apply,
                        assembled_bytes,
                    )
                });
                let (asm_setup, mf_setup, asm_apply, mf_apply, p_pi_bytes) = out.swap_remove(0);
                vec![
                    ("threads".to_string(), nt as f64),
                    ("asm_setup_ms".to_string(), asm_setup * 1e3),
                    ("mf_setup_ms".to_string(), mf_setup * 1e3),
                    ("asm_apply_ms".to_string(), asm_apply * 1e3),
                    ("mf_apply_ms".to_string(), mf_apply * 1e3),
                    (
                        "p_pi_MiB".to_string(),
                        p_pi_bytes as f64 / (1 << 20) as f64,
                    ),
                ]
            });
        }
    }
    par::set_threads(1);

    // --- discount_mode dimension: Scalar vs constant PerStateAction --------
    // The generalized-discounting layer's performance promise: reading the
    // per-row factor from a vector instead of a scalar costs <5% on the
    // fused matfree path (one predictable indexed load per state), and the
    // outputs are bitwise identical (the representation invariant).
    for n in [100_000usize] {
        if n > max_n {
            println!("discount_mode/n={n}: skipped (MADUPITE_BENCH_MAX_N={max_n})");
            continue;
        }
        let base = Arc::new(random_mdp_bench(33, n, 4, 0.99, 5));
        let psa = Arc::new(
            Mdp::new_discounted(
                n,
                4,
                base.transitions().clone(),
                base.costs().to_vec(),
                Discount::constant(DiscountMode::PerStateAction, 0.99, n, 4),
            )
            .unwrap(),
        );
        suite.case(&format!("discount_mode/n={n}"), move || {
            let mut times = Vec::new();
            let mut bits: Option<u64> = None;
            for mdp in [&base, &psa] {
                let mdp2 = Arc::clone(mdp);
                let mut out = World::run(1, move |comm| {
                    let d = DistMdp::from_serial(&comm, &mdp2);
                    let nl = d.local_states();
                    let policy: Vec<usize> = (0..nl).map(|s| s % d.n_actions()).collect();
                    let x: Vec<f64> = (0..nl).map(|i| (i as f64 * 0.01).sin()).collect();
                    let mut y = vec![0.0; nl];
                    let mf = MatFreePolicyOp::new(&d, &policy);
                    let mut buf = mf.make_buffer();
                    let t0 = Instant::now();
                    for _ in 0..10 {
                        mf.apply(&comm, &x, &mut y, &mut buf);
                    }
                    let apply_s = t0.elapsed().as_secs_f64() / 10.0;

                    let mut tv = vec![0.0; nl];
                    let mut pol = vec![0usize; nl];
                    let mut q = Vec::new();
                    let mut bbuf = d.make_buffer();
                    let t0 = Instant::now();
                    d.bellman_backup(&comm, &x, &mut tv, &mut pol, &mut bbuf, &mut q);
                    let backup_s = t0.elapsed().as_secs_f64();
                    (bits_checksum(&y) ^ bits_checksum(&tv), apply_s, backup_s)
                });
                let (b, apply_s, backup_s) = out.swap_remove(0);
                match bits {
                    None => bits = Some(b),
                    Some(want) => {
                        assert_eq!(want, b, "discount representations not bitwise identical")
                    }
                }
                times.push((apply_s, backup_s));
            }
            let overhead = times[1].0 / times[0].0 - 1.0;
            if overhead > 0.05 {
                // timing noise, not correctness — report, don't abort
                eprintln!(
                    "WARNING: per-state-action apply overhead {:.1}% above the \
                     5% target (noisy sample?)",
                    overhead * 100.0
                );
            }
            vec![
                ("scalar_apply_ms".to_string(), times[0].0 * 1e3),
                ("psa_apply_ms".to_string(), times[1].0 * 1e3),
                ("scalar_backup_ms".to_string(), times[0].1 * 1e3),
                ("psa_backup_ms".to_string(), times[1].1 * 1e3),
                ("apply_overhead_pct".to_string(), overhead * 100.0),
            ]
        });
    }

    // --- PJRT dense path vs native rust ------------------------------------
    match Engine::load("artifacts") {
        Err(e) => println!("PJRT cases skipped: {e}"),
        Ok(mut engine) => {
            for (n, m) in [(64usize, 4usize), (128, 4), (256, 8)] {
                let t0 = Instant::now();
                let db = DenseBellman::new(&engine, n, m).unwrap();
                let (p, g, v) = random_block(3, n, m);
                // force compile before timing execution
                let _ = db.bellman(&mut engine, &p, &g, &v, 0.95).unwrap();
                let compile = t0.elapsed().as_secs_f64();
                println!("pjrt {n}x{m}: first-call (compile+exec) {}", fmt_time(compile));

                suite.case(&format!("pjrt_bellman/{n}x{m}"), || {
                    let (tv, _) = db.bellman(&mut engine, &p, &g, &v, 0.95).unwrap();
                    vec![("checksum".to_string(), tv[0] as f64)]
                });
                suite.case(&format!("native_bellman/{n}x{m}"), || {
                    let (tv, _) = bellman_dense_native(n, m, &p, &g, &v, 0.95);
                    vec![("checksum".to_string(), tv[0] as f64)]
                });
                suite.case(&format!("pjrt_vi10/{n}x{m}"), || {
                    let out = db.vi_sweeps(&mut engine, &p, &g, &v, 0.95).unwrap();
                    vec![("checksum".to_string(), out[0] as f64)]
                });
            }
        }
    }

    suite.finish();
}
