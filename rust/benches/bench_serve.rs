//! E9 — policy-serving saturation (DESIGN.md §15): queries/sec through the
//! `madupite::serve` stack across the acceptance matrix
//!
//!   store backend {memory, disk} × cache entries {0, 64, unbounded}
//!   × client threads {1, 4}.
//!
//! Workload: three solved maze policies persisted to the store; every query
//! is the full serving path — `PolicyStore::get` (cache hit or sink read +
//! decode + validation) followed by an `action` and a `value` lookup. With
//! `cache=0` every query pays the decode, isolating the cache's
//! contribution; `disk/cache=0` additionally pays the filesystem read, the
//! worst case a serving deployment can hit.
//!
//! Reported metric: `qps` (queries per second), merged into `BENCH_CI.json`
//! by the perf-smoke job with the same drop-out guard as the other suites.

use madupite::api::{run_solve, MdpBuilder};
use madupite::serve::{PolicyStore, QueryEngine};
use madupite::util::args::Options;
use madupite::util::benchkit::Suite;
use std::time::Instant;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join("madupite-bench-serve")
        .join(format!("{name}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Run `clients` threads, each issuing `per_client` full-path queries
/// (store get + action + value); returns achieved queries/sec.
fn saturate(store: &PolicyStore, fps: &[String], clients: usize, per_client: usize) -> f64 {
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            scope.spawn(move || {
                // cheap per-thread LCG for state selection
                let mut x: u64 = 0x9e3779b97f4a7c15 ^ (c as u64);
                for i in 0..per_client {
                    let fp = &fps[(c + i) % fps.len()];
                    let artifact = store.get(fp).unwrap();
                    let engine = QueryEngine::new(artifact);
                    x = x
                        .wrapping_mul(6364136223846793005)
                        .wrapping_add(1442695040888963407);
                    let state = (x % engine.artifact().n_states as u64) as usize;
                    let a = engine.action(state).unwrap();
                    let v = engine.value(state).unwrap();
                    assert!(a < engine.artifact().n_actions && v.is_finite());
                }
            });
        }
    });
    (clients * per_client) as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let mut suite = Suite::new("E9 serve saturation");

    // Three distinct policies (gamma sweep) over a small maze — enough to
    // exercise cache churn without dominating the run with solve time.
    let outcomes: Vec<_> = ["0.9", "0.95", "0.99"]
        .iter()
        .map(|gamma| {
            let db = Options::parse(
                ["-model", "maze", "-rows", "12", "-cols", "12", "-gamma", gamma]
                    .iter()
                    .map(|s| s.to_string()),
            );
            let builder = MdpBuilder::from_options(&db).unwrap();
            run_solve(&builder, &db).unwrap()
        })
        .collect();
    println!(
        "workload: {} maze policies × (get + action + value) per query",
        outcomes.len()
    );

    let per_client = 2_000usize;
    for backend in ["memory", "disk"] {
        for (cache_label, cache) in [("0", 0usize), ("64", 64), ("unbounded", usize::MAX)] {
            // One store per (backend, cache) point, shared across the
            // thread sweep so the disk artifacts are written once.
            let store = match backend {
                "memory" => PolicyStore::in_memory(cache),
                _ => PolicyStore::on_disk(tmpdir(&format!("c{cache_label}")), cache).unwrap(),
            };
            let fps: Vec<String> = outcomes
                .iter()
                .map(|o| store.put_outcome(o).unwrap())
                .collect();
            let store = std::sync::Arc::new(store);
            for clients in [1usize, 4] {
                let store = std::sync::Arc::clone(&store);
                let fps = fps.clone();
                suite.case(
                    &format!("serve_qps/backend={backend}/cache={cache_label}/threads={clients}"),
                    move || {
                        let qps = saturate(&store, &fps, clients, per_client);
                        assert!(store.cache_len() <= store.cache_capacity());
                        vec![
                            ("qps".to_string(), qps),
                            ("clients".to_string(), clients as f64),
                            ("cache_entries".to_string(), store.cache_len() as f64),
                        ]
                    },
                );
            }
        }
    }

    suite.finish();
}
