"""Layer-2 graph semantics + AOT lowering smoke tests."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref
from .test_kernel import make_mdp


class TestGraphs:
    def test_bellman_min_graph(self):
        p, g, v = make_mdp(1, 16, 3)
        tv, pi = model.bellman_min_graph(p, g, v, 0.9)
        tv_r, pi_r = ref.bellman_min(p, g, v, 0.9)
        np.testing.assert_allclose(tv, tv_r, rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(pi), np.asarray(pi_r))

    @pytest.mark.parametrize("k", [1, 3, 10])
    def test_vi_sweeps_scan_equals_iteration(self, k):
        p, g, v = make_mdp(2, 12, 2)
        (out,) = model.vi_sweeps_graph(p, g, v, 0.9, k)
        expected = ref.vi_sweeps(p, g, v, 0.9, k)
        np.testing.assert_allclose(out, expected, rtol=1e-4, atol=1e-5)

    def test_vi_sweeps_contract_toward_fixed_point(self):
        p, g, v = make_mdp(3, 10, 2)
        (v40,) = model.vi_sweeps_graph(p, g, v, 0.7, 40)
        res = float(ref.bellman_residual(p, g, v40, 0.7))
        assert res < 1e-4, res

    def test_residual_graph(self):
        p, g, v = make_mdp(4, 8, 2)
        tv, pi, res = model.residual_graph(p, g, v, 0.9)
        tv_r, _ = ref.bellman_min(p, g, v, 0.9)
        np.testing.assert_allclose(tv, tv_r, rtol=1e-5, atol=1e-6)
        assert abs(float(res) - float(jnp.max(jnp.abs(tv_r - v)))) < 1e-5

    def test_policy_eval_graph(self):
        rng = np.random.default_rng(0)
        n = 24
        p = rng.random((n, n), dtype=np.float32)
        p /= p.sum(axis=1, keepdims=True)
        g = rng.random(n, dtype=np.float32)
        v = rng.standard_normal(n).astype(np.float32)
        (out,) = model.policy_eval_graph(
            jnp.asarray(p), jnp.asarray(g), jnp.asarray(v), 0.95
        )
        np.testing.assert_allclose(
            out, ref.policy_eval_step(p, g, v, 0.95), rtol=1e-5, atol=1e-6
        )


class TestAotLowering:
    def test_hlo_text_produced(self):
        lowered = aot.lower_bellman(16, 2)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert "f32[2,16,16]" in text  # P input shape present

    def test_vi_lowering_contains_loop(self):
        lowered = aot.lower_vi(8, 2, 5)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        # lax.scan lowers to a while loop in HLO
        assert "while" in text

    def test_policy_eval_lowering(self):
        text = aot.to_hlo_text(aot.lower_policy_eval(8))
        assert "f32[8,8]" in text

    def test_gamma_is_runtime_input(self):
        # gamma must be a parameter (not folded) so one artifact serves all
        text = aot.to_hlo_text(aot.lower_bellman(8, 2))
        # 4 parameters: p, g, v, gamma
        assert text.count("parameter(") >= 4


@pytest.mark.slow
class TestAotEndToEnd:
    def test_cli_writes_artifacts(self, tmp_path):
        out = tmp_path / "artifacts"
        env = dict(os.environ)
        repo_py = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        subprocess.run(
            [
                sys.executable,
                "-m",
                "compile.aot",
                "--out-dir",
                str(out),
                "--shapes",
                "16x2",
                "--sweeps",
                "3",
            ],
            cwd=repo_py,
            env=env,
            check=True,
        )
        files = sorted(os.listdir(out))
        assert "bellman_16_2.hlo.txt" in files
        assert "vi_16_2_k3.hlo.txt" in files
        assert "residual_16_2.hlo.txt" in files
        assert "policy_eval_16.hlo.txt" in files
        manifest = json.loads((out / "manifest.json").read_text())
        assert len(manifest["entries"]) == 4
        shapes = {e["file"]: e for e in manifest["entries"]}
        assert shapes["bellman_16_2.hlo.txt"]["inputs"]["p"] == [2, 16, 16]
