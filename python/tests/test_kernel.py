"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

This is the core correctness signal of the compile path: if these pass, the
HLO artifacts the Rust runtime executes compute exactly what ref.py (and,
transitively, the Rust solver) define.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bellman, ref


def make_mdp(seed, n, m):
    """Random dense row-stochastic MDP block (numpy, f32)."""
    rng = np.random.default_rng(seed)
    p = rng.random((m, n, n), dtype=np.float32) + 1e-3
    p /= p.sum(axis=2, keepdims=True)
    g = rng.random((m, n), dtype=np.float32)
    v = rng.standard_normal(n).astype(np.float32)
    return jnp.asarray(p), jnp.asarray(g), jnp.asarray(v)


class TestBellmanMin:
    @pytest.mark.parametrize("n,m", [(4, 2), (16, 4), (64, 4), (128, 8)])
    def test_matches_ref(self, n, m):
        p, g, v = make_mdp(n * 100 + m, n, m)
        tv_k, pi_k = bellman.bellman_min(p, g, v, 0.95)
        tv_r, pi_r = ref.bellman_min(p, g, v, 0.95)
        np.testing.assert_allclose(tv_k, tv_r, rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(pi_k), np.asarray(pi_r))

    def test_single_action_is_policy_eval(self):
        p, g, v = make_mdp(7, 12, 1)
        tv, pi = bellman.bellman_min(p, g, v, 0.9)
        expected = ref.policy_eval_step(p[0], g[0], v, 0.9)
        np.testing.assert_allclose(tv, expected, rtol=1e-5)
        assert np.all(np.asarray(pi) == 0)

    def test_gamma_zero_reduces_to_cost_min(self):
        p, g, v = make_mdp(9, 10, 3)
        tv, pi = bellman.bellman_min(p, g, v, 0.0)
        np.testing.assert_allclose(tv, jnp.min(g, axis=0), rtol=1e-6)
        np.testing.assert_array_equal(
            np.asarray(pi), np.asarray(jnp.argmin(g, axis=0))
        )

    def test_tie_breaks_to_lowest_action(self):
        # identical actions -> argmin must be 0 everywhere (matches rust)
        n, m = 8, 3
        p = jnp.tile(jnp.eye(n, dtype=jnp.float32)[None], (m, 1, 1))
        g = jnp.ones((m, n), jnp.float32)
        v = jnp.zeros((n,), jnp.float32)
        _, pi = bellman.bellman_min(p, g, v, 0.9)
        assert np.all(np.asarray(pi) == 0)

    def test_contraction_property(self):
        p, g, _ = make_mdp(11, 20, 4)
        u = jnp.asarray(np.random.default_rng(1).standard_normal(20), jnp.float32)
        w = jnp.asarray(np.random.default_rng(2).standard_normal(20), jnp.float32)
        gamma = 0.9
        tu, _ = bellman.bellman_min(p, g, u, gamma)
        tw, _ = bellman.bellman_min(p, g, w, gamma)
        lhs = float(jnp.max(jnp.abs(tu - tw)))
        rhs = gamma * float(jnp.max(jnp.abs(u - w)))
        assert lhs <= rhs + 1e-5

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=48),
        m=st.integers(min_value=1, max_value=8),
        gamma=st.floats(min_value=0.0, max_value=0.999),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_shapes_and_discounts(self, n, m, gamma, seed):
        p, g, v = make_mdp(seed, n, m)
        tv_k, pi_k = bellman.bellman_min(p, g, v, gamma)
        tv_r, pi_r = ref.bellman_min(p, g, v, gamma)
        np.testing.assert_allclose(tv_k, tv_r, rtol=1e-4, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(pi_k), np.asarray(pi_r))


class TestPolicyEval:
    @pytest.mark.parametrize("n", [4, 32, 128])
    def test_matches_ref(self, n):
        rng = np.random.default_rng(n)
        p = rng.random((n, n), dtype=np.float32)
        p /= p.sum(axis=1, keepdims=True)
        g = rng.random(n, dtype=np.float32)
        v = rng.standard_normal(n).astype(np.float32)
        out = bellman.policy_eval_step(
            jnp.asarray(p), jnp.asarray(g), jnp.asarray(v), 0.9
        )
        expected = ref.policy_eval_step(p, g, v, 0.9)
        np.testing.assert_allclose(out, expected, rtol=1e-5, atol=1e-6)

    def test_fixed_point_of_identity_chain(self):
        # P = I, g = 0: V' = gamma * V
        n = 16
        p = jnp.eye(n, dtype=jnp.float32)
        g = jnp.zeros((n,), jnp.float32)
        v = jnp.arange(n, dtype=jnp.float32)
        out = bellman.policy_eval_step(p, g, v, 0.5)
        np.testing.assert_allclose(out, 0.5 * v, rtol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=40),
        gamma=st.floats(min_value=0.0, max_value=0.999),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis(self, n, gamma, seed):
        rng = np.random.default_rng(seed)
        p = rng.random((n, n), dtype=np.float32)
        p /= p.sum(axis=1, keepdims=True)
        g = rng.random(n, dtype=np.float32)
        v = rng.standard_normal(n).astype(np.float32)
        out = bellman.policy_eval_step(
            jnp.asarray(p), jnp.asarray(g), jnp.asarray(v), gamma
        )
        expected = ref.policy_eval_step(p, g, v, gamma)
        np.testing.assert_allclose(out, expected, rtol=1e-4, atol=1e-5)


class TestRefSelfConsistency:
    def test_ref_vi_sweeps_composes(self):
        p, g, v = make_mdp(3, 10, 2)
        once = ref.vi_sweeps(p, g, v, 0.9, 1)
        tv, _ = ref.bellman_min(p, g, v, 0.9)
        np.testing.assert_allclose(once, tv, rtol=1e-6)
        thrice = ref.vi_sweeps(p, g, v, 0.9, 3)
        manual = v
        for _ in range(3):
            manual, _ = ref.bellman_min(p, g, manual, 0.9)
        np.testing.assert_allclose(thrice, manual, rtol=1e-6)

    def test_residual_zero_at_fixed_point(self):
        # run VI to near-convergence, residual must be small
        p, g, v = make_mdp(5, 12, 3)
        x = v
        for _ in range(600):
            x, _ = ref.bellman_min(p, g, x, 0.8)
        assert float(ref.bellman_residual(p, g, x, 0.8)) < 1e-4

    def test_float64_cross_check(self):
        # f32 kernel against f64 reference: bounds the kernel's rounding
        p, g, v = make_mdp(13, 32, 4)
        tv_k, _ = bellman.bellman_min(p, g, v, 0.99)
        p64, g64, v64 = (
            np.asarray(p, np.float64),
            np.asarray(g, np.float64),
            np.asarray(v, np.float64),
        )
        q = g64 + 0.99 * np.einsum("ast,t->as", p64, v64)
        tv64 = q.min(axis=0)
        np.testing.assert_allclose(np.asarray(tv_k, np.float64), tv64, atol=1e-4)


class TestBellmanBatch:
    @pytest.mark.parametrize("n,m,b", [(8, 2, 1), (32, 4, 4), (64, 4, 16)])
    def test_batch_columns_match_single(self, n, m, b):
        p, g, _ = make_mdp(n + m + b, n, m)
        rng = np.random.default_rng(b)
        vb = rng.standard_normal((n, b)).astype(np.float32)
        out = bellman.bellman_min_batch(p, g, jnp.asarray(vb), 0.95)
        for j in range(b):
            tv_j, _ = ref.bellman_min(p, g, vb[:, j], 0.95)
            np.testing.assert_allclose(out[:, j], tv_j, rtol=1e-4, atol=1e-5)

    def test_batch_of_one_equals_scalar_kernel(self):
        p, g, v = make_mdp(17, 12, 3)
        out = bellman.bellman_min_batch(p, g, v[:, None], 0.9)
        tv, _ = bellman.bellman_min(p, g, v, 0.9)
        np.testing.assert_allclose(out[:, 0], tv, rtol=1e-5, atol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=32),
        m=st.integers(min_value=1, max_value=6),
        b=st.integers(min_value=1, max_value=8),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_batched(self, n, m, b, seed):
        p, g, _ = make_mdp(seed, n, m)
        rng = np.random.default_rng(seed % 1000)
        vb = rng.standard_normal((n, b)).astype(np.float32)
        out = bellman.bellman_min_batch(p, g, jnp.asarray(vb), 0.9)
        q = np.asarray(g)[:, :, None] + 0.9 * np.einsum(
            "ast,tb->asb", np.asarray(p), vb
        )
        expected = q.min(axis=0)
        np.testing.assert_allclose(out, expected, rtol=1e-4, atol=1e-5)
