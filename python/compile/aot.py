"""AOT lowering: jax graphs -> HLO **text** artifacts for the Rust runtime.

Run once at build time (`make artifacts`); the Rust binary is self-contained
afterwards. HLO *text* — not serialized HloModuleProto — is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README
and DESIGN.md §2).

Artifacts written to --out-dir (default ../artifacts):
  bellman_<S>_<A>.hlo.txt        (P, G, V, gamma) -> (TV, PI)
  vi_<S>_<A>_k<K>.hlo.txt        (P, G, V, gamma) -> (V_k,)
  policy_eval_<S>.hlo.txt        (P_pi, g_pi, V, gamma) -> (V',)
  residual_<S>_<A>.hlo.txt       (P, G, V, gamma) -> (TV, PI, res)
  manifest.json                   shape/entry-point index for the runtime
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Dense-block shapes shipped by default: (n_states, n_actions).
DEFAULT_SHAPES = [(64, 4), (128, 4), (256, 8)]
DEFAULT_SWEEPS = 10


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_bellman(n, m):
    fn = jax.jit(model.bellman_min_graph)
    return fn.lower(_spec((m, n, n)), _spec((m, n)), _spec((n,)), _spec(()))


def lower_vi(n, m, k):
    fn = jax.jit(lambda p, g, v, gamma: model.vi_sweeps_graph(p, g, v, gamma, k))
    return fn.lower(_spec((m, n, n)), _spec((m, n)), _spec((n,)), _spec(()))


def lower_policy_eval(n):
    fn = jax.jit(model.policy_eval_graph)
    return fn.lower(_spec((n, n)), _spec((n,)), _spec((n,)), _spec(()))


def lower_residual(n, m):
    fn = jax.jit(model.residual_graph)
    return fn.lower(_spec((m, n, n)), _spec((m, n)), _spec((n,)), _spec(()))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument(
        "--shapes",
        default=",".join(f"{n}x{m}" for n, m in DEFAULT_SHAPES),
        help="comma list of SxA dense block shapes, e.g. 64x4,128x4",
    )
    ap.add_argument("--sweeps", type=int, default=DEFAULT_SWEEPS)
    args = ap.parse_args()

    shapes = []
    for tok in args.shapes.split(","):
        n, m = tok.lower().split("x")
        shapes.append((int(n), int(m)))

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"version": 1, "sweeps": args.sweeps, "entries": []}

    def emit(name, lowered, inputs, outputs):
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append(
            {"file": name, "inputs": inputs, "outputs": outputs}
        )
        print(f"wrote {path} ({len(text)} chars)")

    for n, m in shapes:
        emit(
            f"bellman_{n}_{m}.hlo.txt",
            lower_bellman(n, m),
            {"p": [m, n, n], "g": [m, n], "v": [n], "gamma": []},
            {"tv": [n], "pi": [n]},
        )
        emit(
            f"vi_{n}_{m}_k{args.sweeps}.hlo.txt",
            lower_vi(n, m, args.sweeps),
            {"p": [m, n, n], "g": [m, n], "v": [n], "gamma": []},
            {"v": [n]},
        )
        emit(
            f"residual_{n}_{m}.hlo.txt",
            lower_residual(n, m),
            {"p": [m, n, n], "g": [m, n], "v": [n], "gamma": []},
            {"tv": [n], "pi": [n], "res": []},
        )
    for n in sorted({n for n, _ in shapes}):
        emit(
            f"policy_eval_{n}.hlo.txt",
            lower_policy_eval(n),
            {"p_pi": [n, n], "g_pi": [n], "v": [n], "gamma": []},
            {"v": [n]},
        )

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote manifest with {len(manifest['entries'])} entries")


if __name__ == "__main__":
    main()
