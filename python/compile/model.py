"""Layer-2 jax compute graphs for the dense-block accelerator.

These are the functions that get AOT-lowered (by `aot.py`) into the HLO
artifacts the Rust runtime executes. Each graph embeds the Layer-1 Pallas
kernels from `kernels/bellman.py`, so kernel and orchestration lower into a
single fused module — Python never runs at solve time.

Graphs:
  - `bellman_min_graph`:   one Bellman backup (TV + argmin policy).
  - `vi_sweeps_graph`:     k fused value-iteration sweeps via `lax.scan`
                           (amortizes PJRT dispatch: one execute() per k
                           sweeps instead of k round-trips).
  - `policy_eval_graph`:   one fixed-policy evaluation sweep.
  - `residual_graph`:      Bellman backup + sup-norm residual in one pass
                           (saves the Rust side a second device round-trip).
"""

import jax
import jax.numpy as jnp

from .kernels import bellman as kernels


def bellman_min_graph(p, g, v, gamma):
    """(P, G, V, gamma) -> (TV, PI)."""
    tv, pi = kernels.bellman_min(p, g, v, gamma)
    return tv, pi


def vi_sweeps_graph(p, g, v, gamma, k):
    """(P, G, V, gamma) -> V after k Bellman sweeps (k is static).

    Uses `lax.scan` so the lowered module contains a single rolled loop
    body — compile time and code size stay flat in k.
    """

    def body(carry, _):
        tv, _ = kernels.bellman_min(p, g, carry, gamma)
        return tv, ()

    out, _ = jax.lax.scan(body, v, xs=None, length=k)
    return (out,)


def policy_eval_graph(p_pi, g_pi, v, gamma):
    """(P_pi, g_pi, V, gamma) -> V' (one T_pi sweep)."""
    return (kernels.policy_eval_step(p_pi, g_pi, v, gamma),)


def residual_graph(p, g, v, gamma):
    """(P, G, V, gamma) -> (TV, PI, ||TV - V||_inf)."""
    tv, pi = kernels.bellman_min(p, g, v, gamma)
    res = jnp.max(jnp.abs(tv - v))
    return tv, pi, res
