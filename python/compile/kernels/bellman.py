"""Layer-1 Pallas kernels: the dense Bellman backup hot-spot.

The paper's solver spends its time applying `B = G + gamma * P V` and
reducing over actions; for the dense-block accelerator path this is the
compute kernel, written in Pallas and embedded in the Layer-2 jax graphs so
it lowers into the same AOT HLO artifact the Rust runtime executes.

TPU design notes (DESIGN.md §7 — the original targets CPU clusters, so this
is an adaptation, not a port):

- grid over actions; grid step `a` computes `q_a = G[a] + gamma * P[a] @ v`
  as an (S, S) x (S,) contraction. On a real TPU the BlockSpec below tiles
  `P[a]` HBM->VMEM in (block_s, S) slabs feeding the MXU, with `v` resident
  in VMEM across all grid steps and the running min/argmin accumulated in
  the output VMEM block (sequential-grid accumulation pattern).
- min/argmin accumulate across grid steps with the `@pl.when` init-else-
  update idiom; ties resolve to the smallest action id, matching ref.py
  and the Rust solver.
- `interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
  custom-calls; interpret mode lowers to plain HLO so the artifact runs on
  the Rust side. Real-TPU lowering would only change `interpret` and the
  block sizes.

VMEM budget (16 MiB/core): the f32 working set per grid step is one
(block_s, S) slab of P + v (S) + q/tv/pi (block_s each). For the shipped
artifact shapes (S <= 512) a full-rows slab fits: S=512 -> 512*512*4 = 1 MiB
slab + 2 KiB v — comfortably under budget with double buffering; block_s
would shrink first for larger S (see DESIGN.md §8 for the roofline table).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bellman_kernel(gamma_ref, p_ref, g_ref, v_ref, tv_ref, pi_ref):
    """Grid step: fold action `a`'s Q-values into the running min/argmin."""
    a = pl.program_id(0)
    gamma = gamma_ref[0]
    # q = G[a] + gamma * P[a] @ v    (p_ref block is (1, S, S))
    q = g_ref[0, :] + gamma * jnp.dot(p_ref[0], v_ref[...])

    @pl.when(a == 0)
    def _init():
        tv_ref[...] = q
        pi_ref[...] = jnp.zeros_like(pi_ref)

    @pl.when(a != 0)
    def _fold():
        better = q < tv_ref[...]
        tv_ref[...] = jnp.where(better, q, tv_ref[...])
        pi_ref[...] = jnp.where(better, jnp.full_like(pi_ref, a), pi_ref[...])


@functools.partial(jax.jit, static_argnames=())
def bellman_min(p, g, v, gamma):
    """Dense Bellman backup via the Pallas kernel.

    Args:
      p: (A, S, S) f32 transition tensor.
      g: (A, S) f32 stage costs.
      v: (S,) f32 value vector.
      gamma: f32 scalar (traced — one artifact serves any discount).

    Returns:
      (tv, pi): (S,) f32 and (S,) int32.
    """
    n_actions, n_states, _ = p.shape
    gamma_arr = jnp.asarray(gamma, jnp.float32).reshape((1,))
    return pl.pallas_call(
        _bellman_kernel,
        grid=(n_actions,),
        in_specs=[
            pl.BlockSpec((1,), lambda a: (0,)),                      # gamma
            pl.BlockSpec((1, n_states, n_states), lambda a: (a, 0, 0)),  # P[a]
            pl.BlockSpec((1, n_states), lambda a: (a, 0)),           # G[a]
            pl.BlockSpec((n_states,), lambda a: (0,)),               # v
        ],
        out_specs=[
            pl.BlockSpec((n_states,), lambda a: (0,)),               # tv
            pl.BlockSpec((n_states,), lambda a: (0,)),               # pi
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_states,), jnp.float32),
            jax.ShapeDtypeStruct((n_states,), jnp.int32),
        ],
        interpret=True,
    )(gamma_arr, p, g, v)


def _policy_eval_kernel(gamma_ref, p_ref, g_ref, v_ref, out_ref):
    """V' = g_pi + gamma * P_pi @ v (single fused sweep)."""
    out_ref[...] = g_ref[...] + gamma_ref[0] * jnp.dot(p_ref[...], v_ref[...])


@jax.jit
def policy_eval_step(p_pi, g_pi, v, gamma):
    """One fixed-policy evaluation sweep via Pallas.

    Args:
      p_pi: (S, S) f32 policy transition matrix.
      g_pi: (S,) f32 policy stage costs.
      v: (S,) f32.
      gamma: f32 scalar.
    """
    (n_states, _) = p_pi.shape
    gamma_arr = jnp.asarray(gamma, jnp.float32).reshape((1,))
    return pl.pallas_call(
        _policy_eval_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((n_states, n_states), lambda i: (0, 0)),
            pl.BlockSpec((n_states,), lambda i: (0,)),
            pl.BlockSpec((n_states,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n_states,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_states,), jnp.float32),
        interpret=True,
    )(gamma_arr, p_pi, g_pi, v)


def _bellman_batch_kernel(gamma_ref, p_ref, g_ref, v_ref, tv_ref):
    """Grid step: fold action `a` into the running min for a BATCH of value
    vectors. q has shape (S, B): an (S, S) x (S, B) matmul — the MXU-shaped
    variant (batch plays the role of the systolic array's second dimension;
    on TPU, B would be padded to a multiple of 128).
    """
    a = pl.program_id(0)
    gamma = gamma_ref[0]
    # (index the Ref first, then add the batch axis on the loaded array —
    # Pallas Ref indexing does not support jnp.newaxis)
    q = g_ref[0, :][:, None] + gamma * jnp.dot(p_ref[0], v_ref[...])

    @pl.when(a == 0)
    def _init():
        tv_ref[...] = q

    @pl.when(a != 0)
    def _fold():
        tv_ref[...] = jnp.minimum(q, tv_ref[...])


@jax.jit
def bellman_min_batch(p, g, v_batch, gamma):
    """Batched Bellman backup: TV for B value vectors in one pass.

    Args:
      p: (A, S, S) f32.
      g: (A, S) f32.
      v_batch: (S, B) f32 — B value vectors as columns.
      gamma: f32 scalar.

    Returns:
      (S, B) f32 minimized backups (no argmin in the batched variant —
      it serves multi-query evaluation, e.g. bounding runs from several
      initial vectors or perturbation analyses).
    """
    n_actions, n_states, _ = p.shape
    batch = v_batch.shape[1]
    gamma_arr = jnp.asarray(gamma, jnp.float32).reshape((1,))
    return pl.pallas_call(
        _bellman_batch_kernel,
        grid=(n_actions,),
        in_specs=[
            pl.BlockSpec((1,), lambda a: (0,)),
            pl.BlockSpec((1, n_states, n_states), lambda a: (a, 0, 0)),
            pl.BlockSpec((1, n_states), lambda a: (a, 0)),
            pl.BlockSpec((n_states, batch), lambda a: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n_states, batch), lambda a: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_states, batch), jnp.float32),
        interpret=True,
    )(gamma_arr, p, g, v_batch)
