"""Pure-jnp reference oracle for the Pallas Bellman kernels.

This is the correctness anchor of Layer 1 (DESIGN.md §2): every Pallas
kernel in `bellman.py` must match these definitions to float tolerance, and
pytest + hypothesis sweep shapes/dtypes against them. The definitions also
mirror the Rust implementation (`rust/src/mdp/mod.rs::bellman_backup`) so
the three layers agree on semantics:

    TV(s)  = min_a [ G(s, a) + gamma * sum_s' P(a, s, s') V(s') ]
    PI(s)  = argmin_a [ ... ]                       (first minimum wins)
    V'(s)  = g(s) + gamma * sum_s' P_pi(s, s') V(s')   (policy eval sweep)
"""

import jax.numpy as jnp


def bellman_min(p, g, v, gamma):
    """Dense Bellman backup.

    Args:
      p: (A, S, S) row-stochastic transition tensor.
      g: (A, S) stage costs (action-major layout to match the kernel grid).
      v: (S,) value vector.
      gamma: scalar discount.

    Returns:
      (tv, pi): (S,) minimized backup and (S,) int32 argmin policy.
    """
    # q[a, s] = g[a, s] + gamma * (P[a] @ v)[s]
    q = g + gamma * jnp.einsum("ast,t->as", p, v)
    tv = jnp.min(q, axis=0)
    pi = jnp.argmin(q, axis=0).astype(jnp.int32)
    return tv, pi


def policy_eval_step(p_pi, g_pi, v, gamma):
    """One fixed-policy evaluation sweep: V' = g_pi + gamma * P_pi V."""
    return g_pi + gamma * (p_pi @ v)


def vi_sweeps(p, g, v, gamma, k):
    """k fused value-iteration sweeps (the L2 scan graph's semantics)."""
    tv = v
    for _ in range(k):
        tv, _ = bellman_min(p, g, tv, gamma)
    return tv


def bellman_residual(p, g, v, gamma):
    """Sup-norm Bellman residual ||TV - V||_inf."""
    tv, _ = bellman_min(p, g, v, gamma)
    return jnp.max(jnp.abs(tv - v))
